"""Quantizer suite tests: Algorithm 1, Eq. 2, the hardware projection and
the four baselines — including the paper's worked example and hypothesis
property sweeps."""

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile import quantlib as Q


def relu_samples(n=20000, seed=0, mean=0.3):
    rng = np.random.default_rng(seed)
    return np.maximum(rng.normal(mean, 1.0, n), 0.0)


class TestCodebook:
    def test_paper_worked_example(self):
        """§2.1: centers {0,.125,.25,.5,1,2,4,8} -> refs {0,.0625,...,6}."""
        centers = np.array([0, 0.125, 0.25, 0.5, 1.0, 2.0, 4.0, 8.0])
        refs = Q.refs_from_centers(centers)
        np.testing.assert_allclose(
            refs, [0, 0.0625, 0.1875, 0.375, 0.75, 1.5, 3.0, 6.0])
        # "0.05 falls below R1 and maps to C0=0; 0.07 maps to C1=0.125"
        assert Q.quantize_np(np.array([0.05]), refs, centers)[0] == 0.0
        assert Q.quantize_np(np.array([0.07]), refs, centers)[0] == 0.125

    def test_refs_require_sorted_centers(self):
        with pytest.raises(ValueError):
            Q.refs_from_centers(np.array([1.0, 0.5]))

    def test_padding_preserves_semantics(self):
        centers = np.array([0.0, 1.0, 2.0, 3.0])
        refs = Q.refs_from_centers(centers)
        pc, pr = Q.pad_codebook(centers, refs, Q.MAX_LEVELS)
        x = np.linspace(-1, 5, 100)
        np.testing.assert_allclose(
            Q.quantize_np(x, refs, centers), Q.quantize_np(x, pr, pc))

    def test_quantize_jnp_matches_np(self):
        import jax.numpy as jnp
        centers = np.sort(np.random.default_rng(1).normal(0, 2, 16))
        refs = Q.refs_from_centers(centers)
        pc, pr = Q.pad_codebook(centers, refs)
        x = np.random.default_rng(2).normal(0, 3, (7, 5)).astype(np.float32)
        got = np.asarray(Q.quantize_jnp(jnp.asarray(x), jnp.asarray(pr),
                                        jnp.asarray(pc)))
        want = Q.quantize_np(x, pr.astype(np.float64), pc.astype(np.float64))
        np.testing.assert_allclose(got, want.astype(np.float32), rtol=1e-6)

    def test_cell_budget(self):
        assert Q.cell_budget(4) == 32  # paper: 32 cells for 4-bit NL
        assert Q.cell_budget(1) == 4
        with pytest.raises(ValueError):
            Q.cell_budget(0)

    def test_hw_projection_budget(self):
        xs = relu_samples()
        for bits in (2, 3, 4):
            c = np.sort(Q.fit_kmeans(xs, bits))
            hc, hr = Q.project_to_hardware(c, bits)
            d = np.diff(hr)
            dv = d[d > 0].min()
            cells = np.round(d / dv).sum()
            assert cells <= Q.cell_budget(bits) + 0.5
            assert np.all(np.diff(hc) >= 0)


class TestFitters:
    @pytest.mark.parametrize("name", list(Q.FITTERS))
    def test_fitters_basic(self, name):
        xs = relu_samples()
        for bits in (1, 3, 5):
            c = Q.FITTERS[name](xs, bits)
            assert len(c) == 2 ** bits
            assert np.all(np.diff(np.sort(c)) >= 0)

    @pytest.mark.parametrize("name", list(Q.FITTERS))
    def test_fitters_reject_bad_bits(self, name):
        with pytest.raises(ValueError):
            Q.FITTERS[name](relu_samples(100), 0)
        with pytest.raises(ValueError):
            Q.FITTERS[name](relu_samples(100), 8)

    def test_linear_is_uniform(self):
        c = Q.fit_linear(np.array([0.0, 8.0]), 3)
        np.testing.assert_allclose(np.diff(c), np.diff(c)[0])

    def test_cdf_equal_mass_on_uniform(self):
        xs = np.linspace(0, 1, 10001)
        c = Q.fit_cdf(xs, 2)
        np.testing.assert_allclose(c, [0.125, 0.375, 0.625, 0.875], atol=5e-3)

    def test_kmeans_recovers_clusters(self):
        rng = np.random.default_rng(3)
        xs = np.concatenate([rng.normal(m, 0.05, 500) for m in (0, 5, 10, 15)])
        c = np.sort(Q.fit_kmeans(xs, 2))
        np.testing.assert_allclose(c, [0, 5, 10, 15], atol=0.3)

    def test_nonlinear_beats_linear_on_relu(self):
        xs = relu_samples()
        for name in ("lloyd_max", "kmeans", "bs_kmq"):
            cl = Q.Codebook.from_centers(Q.FITTERS[name](xs, 3))
            lin = Q.Codebook.from_centers(Q.fit_linear(xs, 3))
            assert Q.mse(xs, cl.refs, cl.centers) < Q.mse(xs, lin.refs,
                                                          lin.centers)


class TestBsKmq:
    def test_streaming_range_is_outlier_robust(self):
        rng = np.random.default_rng(5)
        xs = relu_samples(50000, 5)
        idx = rng.choice(50000, 80, replace=False)
        xs[idx] = 1e4  # 0.16% giant outliers, spread across batches
        c = Q.fit_bs_kmq(xs, 4)
        assert c[-1] < 100, f"g_max contaminated: {c[-1]}"

    def test_bounds_are_centers(self):
        xs = relu_samples()
        c = Q.fit_bs_kmq(xs, 3)
        assert abs(c[0]) < 1e-6  # g_min ~ 0 for ReLU data
        assert len(c) == 8

    def test_one_bit(self):
        c = Q.fit_bs_kmq(relu_samples(1000), 1)
        assert len(c) == 2

    def test_calibrator_requires_observation(self):
        calib = Q.BSKMQCalibrator()
        with pytest.raises(RuntimeError):
            calib.finish(3)

    def test_ema_follows_eq1(self):
        calib = Q.BSKMQCalibrator(alpha=0.0)
        calib.observe(np.array([0.0, 10.0]))
        assert calib.g_min == 0.0 and calib.g_max == 10.0
        calib.observe(np.array([2.0, 20.0]))
        assert calib.g_min == pytest.approx(0.9 * 0.0 + 0.1 * 2.0)
        assert calib.g_max == pytest.approx(0.9 * 10.0 + 0.1 * 20.0)

    def test_wins_under_hw_projection_on_spiky_data(self):
        rng = np.random.default_rng(7)
        xs = np.maximum(rng.normal(0.0, 1.0, 40000), 0.0)
        out = rng.lognormal(1.5, 0.9, 200)
        xs = np.concatenate([xs, out])
        bits = 3
        wins = 0
        for name in ("linear", "cdf", "kmeans"):
            c = np.sort(Q.FITTERS[name](xs, bits))
            hc, hr = Q.project_to_hardware(c, bits)
            base = float(np.mean((xs - Q.quantize_np(xs, hr, hc)) ** 2))
            cb = np.sort(Q.fit_bs_kmq(xs, bits))
            hc2, hr2 = Q.project_to_hardware(cb, bits)
            ours = float(np.mean((xs - Q.quantize_np(xs, hr2, hc2)) ** 2))
            wins += ours < base
        assert wins >= 2, f"bs_kmq won only {wins}/3 baselines"


@settings(max_examples=25, deadline=None)
@given(
    st.integers(min_value=1, max_value=6),
    st.floats(min_value=-3.0, max_value=3.0),
    st.floats(min_value=0.05, max_value=4.0),
    st.integers(min_value=0, max_value=10_000),
)
def test_property_quantize_is_nearest_center(bits, mu, sigma, seed):
    """Any fitted codebook + Eq. 2 refs implement nearest-center rounding."""
    rng = np.random.default_rng(seed)
    xs = rng.normal(mu, sigma, 500)
    centers = np.sort(Q.fit_kmeans(xs, bits, seed=seed))
    refs = Q.refs_from_centers(centers)
    x = rng.normal(mu, sigma * 2, 50)
    q = Q.quantize_np(x, refs, centers)
    # brute-force nearest
    near = centers[np.argmin(np.abs(x[:, None] - centers[None, :]), axis=1)]
    np.testing.assert_allclose(np.abs(x - q), np.abs(x - near), atol=1e-9)


@settings(max_examples=20, deadline=None)
@given(st.integers(min_value=2, max_value=6),
       st.integers(min_value=0, max_value=1000))
def test_property_mse_decreases_with_bits(bits, seed):
    xs = np.maximum(np.random.default_rng(seed).normal(0.2, 1.0, 2000), 0)
    cb_lo = Q.Codebook.from_centers(Q.fit_bs_kmq(xs, bits - 1))
    cb_hi = Q.Codebook.from_centers(Q.fit_bs_kmq(xs, bits))
    assert Q.mse(xs, cb_hi.refs, cb_hi.centers) <= \
        Q.mse(xs, cb_lo.refs, cb_lo.centers) * 1.25 + 1e-9
