"""Synthetic calibration/training datasets (DESIGN.md §5 substitution).

The paper's datasets (CIFAR-10/100, Tiny-ImageNet, SQuAD) are replaced by
procedurally generated tasks of matching *statistical* character:

* images — per-class smooth low-frequency templates (bilinear-upsampled
  random 4x4 fields) plus per-sample Gaussian noise, so early conv layers
  see natural-image-like spatially correlated inputs and their BN-ReLU
  activations form the zero-spiked, tailed distributions Fig. 1 studies;
* token sequences — class-conditioned bigram chains over a small vocab,
  giving attention layers realistic low-entropy structure.

The Rust side (`rust/src/data`) re-implements the same generators with the
same parameterization for pure-Rust workloads.
"""

import numpy as np


def _smooth_template(rng, hw, channels):
    """Random 4x4 field bilinearly upsampled to hw x hw (low-frequency)."""
    coarse = rng.normal(size=(4, 4, channels))
    # bilinear upsample 4x4 -> hw x hw
    src = np.linspace(0, 3, hw)
    i0 = np.clip(src.astype(int), 0, 2)
    frac = src - i0
    rows = (coarse[i0] * (1 - frac)[:, None, None]
            + coarse[i0 + 1] * frac[:, None, None])
    cols = (rows[:, i0] * (1 - frac)[None, :, None]
            + rows[:, i0 + 1] * frac[None, :, None])
    return cols


#: templates/transition matrices are the *task* — fixed across train/test
#: splits (only the sample seed varies), like CIFAR's classes are fixed.
TASK_SEED = 9991


def make_image_dataset(seed: int, n: int, hw: int = 16, channels: int = 3,
                       classes: int = 10, noise: float = 0.6,
                       template_gain: float = 1.4):
    """Class-template images: ``(x [n,hw,hw,c] f32, y [n] i32)``."""
    trng = np.random.default_rng(TASK_SEED + classes)
    templates = np.stack([_smooth_template(trng, hw, channels)
                          for _ in range(classes)])
    rng = np.random.default_rng(seed)
    y = rng.integers(0, classes, size=n)
    x = (template_gain * templates[y]
         + noise * rng.normal(size=(n, hw, hw, channels)))
    # ~1.2% "exposure outliers": natural-image datasets contain rare
    # high-contrast samples whose activations form the heavy tails that
    # Fig. 1's NL quantizers must cope with (DESIGN.md §5).
    hot = rng.random(n) < 0.012
    x[hot] *= rng.uniform(2.5, 4.0, size=(hot.sum(), 1, 1, 1))
    return x.astype(np.float32), y.astype(np.int32)


def make_token_dataset(seed: int, n: int, seq_len: int = 32, vocab: int = 64,
                       classes: int = 6, temp: float = 1.2):
    """Class-conditioned bigram sequences: ``(x [n,T] i32, y [n] i32)``."""
    trng = np.random.default_rng(TASK_SEED + vocab)
    # one transition matrix per class (fixed task, shared by all splits)
    trans = trng.normal(size=(classes, vocab, vocab)) * temp
    trans = np.exp(trans - trans.max(axis=-1, keepdims=True))
    trans /= trans.sum(axis=-1, keepdims=True)
    rng = np.random.default_rng(seed)
    y = rng.integers(0, classes, size=n)
    x = np.empty((n, seq_len), dtype=np.int32)
    x[:, 0] = rng.integers(0, vocab, size=n)
    for t in range(1, seq_len):
        probs = trans[y, x[:, t - 1]]
        cum = probs.cumsum(axis=-1)
        u = rng.random(n)[:, None]
        x[:, t] = (u > cum).sum(axis=-1)
    return x, y.astype(np.int32)


def dataset_for(model_name: str, seed: int, n: int):
    """Dataset matched to a model's input contract (see models/*)."""
    if model_name == "distilbert":
        return make_token_dataset(seed, n)
    if model_name == "vgg":
        return make_image_dataset(seed, n, classes=20)
    return make_image_dataset(seed, n, classes=10)
