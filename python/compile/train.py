"""Build-time training + quantization-aware fine-tuning (Fig. 5's FT rows).

Runs ONCE during `make artifacts` (never on the request path):

1. trains each mini model on its synthetic dataset (hand-rolled Adam —
   no optax on this testbed);
2. exports the BN-folded inference pack to ``artifacts/<model>_weights.bin``
   (the tensors the Rust runtime feeds the AOT graphs);
3. calibrates per-layer BS-KMQ / linear codebooks python-side, evaluates
   PTQ, then low-bit fine-tunes with STE fake quantization at the paper's
   per-model bit widths (3/3/4/4b) and records everything in
   ``artifacts/train_results.json`` for the Fig. 5 harness.
"""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from . import data as D
from . import quantlib as Q
from . import weights_io
from .models import MODELS
from .models import common as cm

TRAIN_N = 2048
TEST_N = 512
BATCH = 64
STEPS = {"resnet": 350, "vgg": 350, "inception": 300, "distilbert": 900}
LR = 3e-3
FT_STEPS = 200
FT_LR = 1e-4
#: the paper's chosen per-model NL-ADC resolutions (Fig. 5)
PAPER_BITS = {"resnet": 3, "vgg": 3, "inception": 4, "distilbert": 4}
CALIB_BATCHES = 8


# ----------------------------------------------------------------- optimizer

def adam_init(params):
    z = jax.tree_util.tree_map(jnp.zeros_like, params)
    return {"m": z, "v": jax.tree_util.tree_map(jnp.zeros_like, params),
            "t": jnp.zeros((), jnp.int32)}


def adam_update(params, grads, opt, lr, b1=0.9, b2=0.999, eps=1e-8):
    t = opt["t"] + 1
    m = jax.tree_util.tree_map(lambda m, g: b1 * m + (1 - b1) * g,
                               opt["m"], grads)
    v = jax.tree_util.tree_map(lambda v, g: b2 * v + (1 - b2) * g * g,
                               opt["v"], grads)
    mhat_scale = 1.0 / (1 - b1 ** t)
    vhat_scale = 1.0 / (1 - b2 ** t)
    new = jax.tree_util.tree_map(
        lambda p, m, v: p - lr * (m * mhat_scale)
        / (jnp.sqrt(v * vhat_scale) + eps),
        params, m, v)
    return new, {"m": m, "v": v, "t": t}


def cross_entropy(logits, y):
    logp = jax.nn.log_softmax(logits)
    return -jnp.mean(jnp.take_along_axis(logp, y[:, None], axis=1))


# ------------------------------------------------------------------ training

def train_model(name, mod, seed=0):
    x, y = D.dataset_for(name, seed=seed, n=TRAIN_N)
    xt, yt = D.dataset_for(name, seed=seed + 1, n=TEST_N)
    key = jax.random.PRNGKey(seed)
    params = mod.init_params(key)
    state = mod.init_state()
    opt = adam_init(params)

    def loss_fn(params, state, xb, yb):
        logits, ns = mod.forward_train(params, state, xb, True)
        return cross_entropy(logits, yb), ns

    @jax.jit
    def step(params, state, opt, xb, yb):
        (loss, ns), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            params, state, xb, yb)
        params, opt = adam_update(params, grads, opt, LR)
        return params, ns, opt, loss

    rng = np.random.default_rng(seed)
    n_steps = STEPS[name]
    for i in range(n_steps):
        idx = rng.integers(0, TRAIN_N, BATCH)
        params, state, opt, loss = step(params, state, opt, x[idx], y[idx])
        if i % 100 == 0:
            print(f"  [{name}] step {i} loss {float(loss):.4f}")

    @jax.jit
    def infer(params, state, xb):
        return mod.forward_train(params, state, xb, False)[0]

    acc = float(jnp.mean(jnp.argmax(infer(params, state, xt), -1) == yt))
    print(f"  [{name}] float test acc {acc:.4f}")
    return params, state, (x, y), (xt, yt), acc


# ------------------------------------------------------- PTQ / FT evaluation

def calibrate_codebooks(mod, pack, x_calib, bits, method="bs_kmq"):
    """Collect activations per quantized layer, fit + hardware-project."""
    nq = len(pack.qspecs)
    calibs = [Q.BSKMQCalibrator(seed=i) for i in range(nq)]
    samples = [[] for _ in range(nq)]
    for b in range(CALIB_BATCHES):
        xb = x_calib[b * 32:(b + 1) * 32]
        ctx = cm.QuantCtx(mode="collect")
        mod.forward_infer(pack, jnp.asarray(xb), ctx)
        for i, rec in enumerate(ctx.records):
            arr = np.asarray(rec)
            samples[i].append(arr)
            calibs[i].observe(arr)
    books = []
    for i in range(nq):
        if method == "bs_kmq":
            centers = calibs[i].finish(bits)
        else:
            alls = np.concatenate(samples[i])
            centers = Q.FITTERS[method](alls, bits)
        hw_c, hw_r = Q.project_to_hardware(np.sort(centers), bits)
        books.append((jnp.asarray(hw_r, jnp.float32),
                      jnp.asarray(hw_c, jnp.float32)))
    return books


def eval_fakequant(mod, pack, books, xt, yt):
    ctx = cm.QuantCtx(mode="fakequant", fq_codebooks=books)
    logits = mod.forward_infer(pack, jnp.asarray(xt), ctx)
    return float(jnp.mean(jnp.argmax(logits, -1) == yt))


def finetune(mod, pack, books, xy, xt, yt, seed=0):
    """STE fake-quant fine-tuning of the folded pack (Fig. 5 FT rows)."""
    x, y = xy
    trainable = {"qw": [list(t) for t in pack.qweights],
                 "dg": pack.digital}

    def rebuild(tr):
        return cm.InferencePack([tuple(t) for t in tr["qw"]], pack.qspecs,
                                tr["dg"])

    def loss_fn(tr, xb, yb):
        ctx = cm.QuantCtx(mode="fakequant", fq_codebooks=books)
        logits = mod.forward_infer(rebuild(tr), jnp.asarray(xb), ctx)
        return cross_entropy(logits, yb)

    opt = adam_init(trainable)

    @jax.jit
    def step(tr, opt, xb, yb):
        loss, grads = jax.value_and_grad(loss_fn)(tr, xb, yb)
        tr, opt = adam_update(tr, grads, opt, FT_LR)
        return tr, opt, loss

    rng = np.random.default_rng(seed)
    for _ in range(FT_STEPS):
        idx = rng.integers(0, x.shape[0], BATCH)
        trainable, opt, _ = step(trainable, opt, x[idx], y[idx])
    return eval_fakequant(mod, rebuild(trainable), books, xt, yt)


# -------------------------------------------------------------------- export

def export_weights(path, pack):
    tensors = []
    for i, ((w, b), spec) in enumerate(zip(pack.qweights, pack.qspecs)):
        tensors.append((f"q{i:02d}_{spec.name}_w", np.asarray(w)))
        tensors.append((f"q{i:02d}_{spec.name}_b", np.asarray(b)))
    for name in sorted(pack.digital):
        v = pack.digital[name]
        if isinstance(v, dict):
            for f in sorted(v):
                tensors.append((f"d_{name}_{f}", np.asarray(v[f])))
        else:
            tensors.append((f"d_{name}", np.asarray(v)))
    weights_io.save_tensors(path, tensors)


def main(outdir="../artifacts"):
    os.makedirs(outdir, exist_ok=True)
    results = {}
    for name, mod in MODELS.items():
        print(f"== training {name} ==")
        params, state, (x, y), (xt, yt), float_acc = train_model(name, mod)
        pack = mod.export_pack(params, state)
        export_weights(os.path.join(outdir, f"{name}_weights.bin"), pack)

        bits = PAPER_BITS[name]
        entry = {"float_acc": float_acc, "paper_bits": bits}
        for method in ("bs_kmq", "linear"):
            books = calibrate_codebooks(mod, pack, x, bits, method)
            entry[f"ptq_{method}"] = eval_fakequant(mod, pack, books, xt, yt)
            entry[f"ft_{method}"] = finetune(mod, pack, books, (x, y), xt, yt)
            print(f"  [{name}] {method}@{bits}b "
                  f"PTQ {entry[f'ptq_{method}']:.4f} "
                  f"FT {entry[f'ft_{method}']:.4f}")
        results[name] = entry
    with open(os.path.join(outdir, "train_results.json"), "w") as f:
        json.dump(results, f, indent=2)
    print("wrote train_results.json")


if __name__ == "__main__":
    main()
