"""Pallas kernel: dual-9T crossbar MAC + per-tile ADC conversion (Fig. 2).

The contraction dimension K is split into 256-row crossbar tiles — one
analog accumulation each, exactly the paper's macro geometry.  Each grid
step computes one tile's MAC (``x_tile @ w_tile``), adds the tile's ADC
conversion noise, converts through the programmable reference ladder
(floor-ADC bucketize -> center map), and digitally accumulates into the
output block, mirroring the ADC-then-digital-accumulate dataflow.

BlockSpec schedule (DESIGN.md §7): the codebook stays VMEM-resident across
the whole grid; ``x``/``w``/``noise`` stream tile-by-tile along K — the
role the PWM input sequencing plays in the silicon macro.  ``interpret=True``
is mandatory on this CPU testbed; numerics are pinned to
``ref.ref_imc_mac_adc`` by the pytest + hypothesis suite.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .nl_quant import _quantize_block
from .ref import CROSSBAR_ROWS


def _imc_mac_kernel(x_ref, w_ref, refs_ref, centers_ref, noise_ref, o_ref, *,
                    use_onehot):
    """One K-tile: analog MAC -> +noise -> ADC -> digital accumulate."""
    t = pl.program_id(0)
    partial = jnp.dot(x_ref[...], w_ref[...],
                      preferred_element_type=jnp.float32)
    partial = partial + noise_ref[0]
    q = _quantize_block(partial, refs_ref[...], centers_ref[...], use_onehot)

    @pl.when(t == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += q


@functools.partial(jax.jit, static_argnames=("tile_k", "interpret"))
def imc_mac_adc(x, w, refs, centers, noise=None, *,
                tile_k: int = CROSSBAR_ROWS, interpret: bool = True):
    """Crossbar-tiled MAC with per-tile ADC quantization.

    Args:
      x: ``[M, K]`` activations (im2col'd convolutions or token matrices).
      w: ``[K, N]`` weights, BN folded.
      refs, centers: ``[L]`` padded codebook programmed into the NL-ADC.
      noise: ``[Kt, M, N]`` pre-scaled conversion noise, or None.
      tile_k: crossbar rows (256 for the paper's macro).

    Returns ``[M, N]`` f32.
    """
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, f"contraction mismatch {k} vs {k2}"
    kt = -(-k // tile_k)
    pad = kt * tile_k - k
    if pad:  # zero rows add nothing to the analog MAC
        x = jnp.pad(x, ((0, 0), (0, pad)))
        w = jnp.pad(w, ((0, pad), (0, 0)))
    if noise is None:
        noise = jnp.zeros((kt, m, n), dtype=jnp.float32)
    levels = refs.shape[0]
    use_onehot = m * n * levels <= 1 << 21
    kernel = functools.partial(_imc_mac_kernel, use_onehot=use_onehot)
    return pl.pallas_call(
        kernel,
        grid=(kt,),
        in_specs=[
            pl.BlockSpec((m, tile_k), lambda t: (0, t)),
            pl.BlockSpec((tile_k, n), lambda t: (t, 0)),
            pl.BlockSpec((levels,), lambda t: (0,)),
            pl.BlockSpec((levels,), lambda t: (0,)),
            pl.BlockSpec((1, m, n), lambda t: (t, 0, 0)),
        ],
        out_specs=pl.BlockSpec((m, n), lambda t: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=interpret,
    )(x.astype(jnp.float32), w.astype(jnp.float32), refs, centers, noise)
