"""Pallas kernel: the IM NL-ADC conversion (floor-ADC bucketize + center map).

This is the paper's ADC as a kernel: compare the analog value against the
programmable reference ladder (thermometer comparison, exactly what the 128
shared sense amplifiers do against the common ramp), sum the thermometer
code to an index (the ripple counter), and map the index to its digital
center (the Fig. 3(b) output mapping).

TPU adaptation (DESIGN.md §7): the codebook (<=128 f32 levels) lives in
VMEM for the whole grid; the thermometer comparison is a vectorized
broadcast against it, and the center map is expressed as a one-hot × centers
contraction when the tile is small enough for the MXU to win, otherwise a
gather.  Under ``interpret=True`` both paths are validated against
``ref.ref_nl_quantize``.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

#: one-hot matmul path only below this tile volume (elements * levels)
_ONEHOT_LIMIT = 1 << 21


def _quantize_block(x, refs, centers, use_onehot: bool):
    idx = jnp.sum(x[..., None] >= refs, axis=-1) - 1
    idx = jnp.clip(idx, 0, centers.shape[0] - 1)
    if use_onehot:
        # MXU-friendly: one-hot(idx) @ centers.
        onehot = (idx[..., None] == jnp.arange(centers.shape[0])).astype(
            centers.dtype
        )
        return jnp.einsum("...l,l->...", onehot, centers)
    return jnp.take(centers, idx)


def _nl_quant_kernel(x_ref, refs_ref, centers_ref, o_ref, *, use_onehot):
    o_ref[...] = _quantize_block(
        x_ref[...], refs_ref[...], centers_ref[...], use_onehot
    )


@functools.partial(jax.jit, static_argnames=("interpret",))
def nl_quantize(x, refs, centers, *, interpret: bool = True):
    """Quantize ``x`` (any shape, f32) against a padded codebook ``[L]``."""
    levels = refs.shape[0]
    use_onehot = x.size * levels <= _ONEHOT_LIMIT
    kernel = functools.partial(_nl_quant_kernel, use_onehot=use_onehot)
    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct(x.shape, jnp.float32),
        interpret=interpret,
    )(x.astype(jnp.float32), refs, centers)
