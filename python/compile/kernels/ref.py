"""Pure-jnp oracle for the Pallas kernels (the correctness reference).

Semantics shared with ``imc_mac.py`` / ``nl_quant.py``:

* ``ref_nl_quantize`` — floor-ADC conversion: index of the largest reference
  level not exceeding the input, mapped to the matching center (paper Eq. 2
  discussion).  Padded codebook slots carry ``+inf`` references and are
  never selected.
* ``ref_imc_mac_adc`` — the dual-9T crossbar dataflow of Fig. 2: the
  contraction dimension is split into 256-row crossbar tiles, each tile's
  analog MAC is converted by the (per-tile) ADC — with optional conversion
  noise in units of the codebook's minimum reference step — and the
  resulting digital codes are accumulated.
"""

import jax.numpy as jnp

#: Crossbar height of the paper's macro (rows per analog accumulation).
CROSSBAR_ROWS = 256


def min_ref_step(refs):
    """Smallest positive finite reference step — the ADC LSB (noise unit)."""
    d = refs[1:] - refs[:-1]
    d = jnp.where(jnp.isfinite(d) & (d > 0), d, jnp.inf)
    step = jnp.min(d)
    return jnp.where(jnp.isfinite(step), step, 1.0)


def ref_nl_quantize(x, refs, centers):
    """Floor-ADC quantization of ``x`` against a (possibly padded) codebook."""
    idx = jnp.sum(x[..., None] >= refs, axis=-1) - 1
    idx = jnp.clip(idx, 0, centers.shape[0] - 1)
    return jnp.take(centers, idx)


def ref_imc_mac_adc(x, w, refs, centers, noise=None, tile_k: int = CROSSBAR_ROWS):
    """Tiled crossbar MAC with per-tile ADC conversion, pure jnp.

    Args:
      x: ``[M, K]`` activations (im2col'd for convs).
      w: ``[K, N]`` weights (BN folded at export time).
      refs, centers: ``[L]`` padded codebook for the per-tile conversion.
      noise: optional ``[Kt, M, N]`` pre-scaled additive conversion noise
        (already multiplied by sigma and the codebook's min step).
      tile_k: crossbar rows per analog tile (256 in the paper's macro).

    Returns ``[M, N]`` digitally accumulated quantized partial sums (f32).
    """
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, f"contraction mismatch {k} vs {k2}"
    kt = -(-k // tile_k)
    acc = jnp.zeros((m, n), dtype=jnp.float32)
    for t in range(kt):
        lo, hi = t * tile_k, min((t + 1) * tile_k, k)
        partial = (x[:, lo:hi] @ w[lo:hi, :]).astype(jnp.float32)
        if noise is not None:
            partial = partial + noise[t]
        acc = acc + ref_nl_quantize(partial, refs, centers)
    return acc
