"""AOT lowering: JAX/Pallas graphs -> HLO *text* artifacts for the Rust runtime.

Interchange is HLO text, NOT serialized HloModuleProto — jax >= 0.5 emits
64-bit instruction ids that xla_extension 0.5.1 rejects; the text parser
reassigns ids (see /opt/xla-example/README.md).

Per model, two graphs are lowered (batch = 32):

* ``<model>_collect.hlo.txt`` — float forward that additionally emits, per
  quantized layer, a 4096-sample activation subsample and the crossbar-tile
  partial-sum absmax.  The Rust calibrator (Algorithm 1) streams batches
  through this graph.  Output: one flat f32 vector
  ``[logits | samples(nq x 4096) | tile_absmax(nq)]``.
* ``<model>_qfwd.hlo.txt`` — the deployed quantized forward (Pallas
  ``imc_mac_adc`` per-tile conversion + per-layer NL-ADC codebooks + LSB
  noise).  Extra runtime args: stacked padded codebooks ``[nq,128]`` x 4,
  ``noise_std`` (sigma in LSB units) and a PRNG ``seed``.  Output: flat
  logits.

Also lowered: ``resnet_qfwd_b1`` (batch-1 serving graph) and ``mac_tile``
(standalone crossbar kernel for microbenches).  A JSON manifest per model
records arg order/shapes and the collect-vector layout for the Rust side.
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import data as D
from . import weights_io
from .kernels.imc_mac import imc_mac_adc
from .models import MODELS
from .models import common as cm
from .quantlib import MAX_LEVELS

BATCH = 32


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    return comp.as_hlo_text()


# ------------------------------------------------------------ pack plumbing

def weight_arg_layout(pack):
    """Canonical flat arg order: q-layer (w, b) pairs, then sorted digital."""
    names, shapes = [], []
    for i, ((w, b), spec) in enumerate(zip(pack.qweights, pack.qspecs)):
        names += [f"q{i:02d}_{spec.name}_w", f"q{i:02d}_{spec.name}_b"]
        shapes += [list(np.shape(w)), list(np.shape(b))]
    for name in sorted(pack.digital):
        v = pack.digital[name]
        if isinstance(v, dict):
            for f in sorted(v):
                names.append(f"d_{name}_{f}")
                shapes.append(list(np.shape(v[f])))
        else:
            names.append(f"d_{name}")
            shapes.append(list(np.shape(v)))
    return names, shapes


def rebuild_pack(template_pack, flat_args):
    """Inverse of :func:`weight_arg_layout` inside the traced graph."""
    nq = len(template_pack.qspecs)
    qweights = [(flat_args[2 * i], flat_args[2 * i + 1]) for i in range(nq)]
    digital = {}
    idx = 2 * nq
    for name in sorted(template_pack.digital):
        v = template_pack.digital[name]
        if isinstance(v, dict):
            digital[name] = {}
            for f in sorted(v):
                digital[name][f] = flat_args[idx]
                idx += 1
        else:
            digital[name] = flat_args[idx]
            idx += 1
    return cm.InferencePack(qweights, template_pack.qspecs, digital)


def load_pack(mod, weights_path):
    """Rebuild a trained InferencePack from the weights container."""
    tensors = dict(weights_io.load_tensors(weights_path))
    template = mod.export_pack(mod.init_params(jax.random.PRNGKey(0)),
                               mod.init_state())
    names, _ = weight_arg_layout(template)
    flat = [jnp.asarray(tensors[n]) for n in names]
    return rebuild_pack(template, flat), template, names


# ------------------------------------------------------------ graph builders

def make_collect_fn(mod, template):
    def collect_fn(x, *wargs):
        pack = rebuild_pack(template, list(wargs))
        ctx = cm.QuantCtx(mode="collect")
        logits = mod.forward_infer(pack, x, ctx)
        parts = [logits.reshape(-1)]
        parts += list(ctx.records)
        parts.append(jnp.stack(ctx.tile_maxes))
        return (jnp.concatenate(parts).astype(jnp.float32),)
    return collect_fn


def make_qfwd_fn(mod, template):
    def qfwd_fn(x, nl_refs, nl_centers, tile_refs, tile_centers,
                noise_std, seed, *wargs):
        pack = rebuild_pack(template, list(wargs))
        ctx = cm.QuantCtx(
            mode="quant", nl_refs=nl_refs, nl_centers=nl_centers,
            tile_refs=tile_refs, tile_centers=tile_centers,
            noise_std=noise_std, key=jax.random.PRNGKey(seed))
        logits = mod.forward_infer(pack, x, ctx)
        return (logits.reshape(-1).astype(jnp.float32),)
    return qfwd_fn


def input_spec(mod, batch):
    if mod.SEQUENCE:
        return jax.ShapeDtypeStruct((batch,) + mod.INPUT_SHAPE, jnp.int32)
    return jax.ShapeDtypeStruct((batch,) + mod.INPUT_SHAPE, jnp.float32)


def lower_model(name, mod, outdir):
    wpath = os.path.join(outdir, f"{name}_weights.bin")
    pack, template, wnames = load_pack(mod, wpath)
    nq = len(pack.qspecs)
    _, wshapes = weight_arg_layout(pack)
    warg_specs = [jax.ShapeDtypeStruct(tuple(s), jnp.float32)
                  for s in wshapes]

    # --- collect graph
    x_spec = input_spec(mod, BATCH)
    lowered = jax.jit(make_collect_fn(mod, template)).lower(
        x_spec, *warg_specs)
    with open(os.path.join(outdir, f"{name}_collect.hlo.txt"), "w") as f:
        f.write(to_hlo_text(lowered))

    # --- qfwd graph(s)
    cb = jax.ShapeDtypeStruct((nq, MAX_LEVELS), jnp.float32)
    scalar = jax.ShapeDtypeStruct((), jnp.float32)
    seed = jax.ShapeDtypeStruct((), jnp.uint32)
    qfwd = make_qfwd_fn(mod, template)
    lowered = jax.jit(qfwd).lower(x_spec, cb, cb, cb, cb, scalar, seed,
                                  *warg_specs)
    with open(os.path.join(outdir, f"{name}_qfwd.hlo.txt"), "w") as f:
        f.write(to_hlo_text(lowered))
    if name == "resnet":
        lowered = jax.jit(qfwd).lower(input_spec(mod, 1), cb, cb, cb, cb,
                                      scalar, seed, *warg_specs)
        with open(os.path.join(outdir, "resnet_qfwd_b1.hlo.txt"), "w") as f:
            f.write(to_hlo_text(lowered))

    # --- manifest
    logits_len = BATCH * mod.NUM_CLASSES
    manifest = {
        "model": name,
        "batch": BATCH,
        "input_shape": list(mod.INPUT_SHAPE),
        "input_dtype": "i32" if mod.SEQUENCE else "f32",
        "num_classes": mod.NUM_CLASSES,
        "max_levels": MAX_LEVELS,
        "qlayers": [{"name": s.name, "k": s.k, "n": s.n, "relu": s.relu}
                    for s in pack.qspecs],
        "weight_args": [{"name": n, "shape": s}
                        for n, s in zip(wnames, wshapes)],
        "collect": {
            "out_len": logits_len + nq * cm.COLLECT_SAMPLES + nq,
            "logits_len": logits_len,
            "samples_per_layer": cm.COLLECT_SAMPLES,
            "tilemax_offset": logits_len + nq * cm.COLLECT_SAMPLES,
        },
        "artifacts": {
            "collect": f"{name}_collect.hlo.txt",
            "qfwd": f"{name}_qfwd.hlo.txt",
            **({"qfwd_b1": "resnet_qfwd_b1.hlo.txt"} if name == "resnet"
               else {}),
        },
    }
    with open(os.path.join(outdir, f"{name}_manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)

    # --- datasets (deterministic by seed; same streams train.py used)
    x_cal, _ = D.dataset_for(name, seed=0, n=8 * BATCH)
    x_test, y_test = D.dataset_for(name, seed=1, n=16 * BATCH)
    weights_io.save_tensors(
        os.path.join(outdir, f"{name}_data.bin"),
        [("x_calib", np.asarray(x_cal, np.float32)),
         ("x_test", np.asarray(x_test, np.float32)),
         ("y_test", np.asarray(y_test, np.float32))])
    print(f"  lowered {name}: nq={nq}, wargs={len(wnames)}")


def lower_mac_tile(outdir, m=64, k=512, n=128):
    """Standalone crossbar-tile kernel graph for microbenches/serving."""
    def fn(x, w, refs, centers):
        return (imc_mac_adc(x, w, refs, centers),)

    specs = (jax.ShapeDtypeStruct((m, k), jnp.float32),
             jax.ShapeDtypeStruct((k, n), jnp.float32),
             jax.ShapeDtypeStruct((MAX_LEVELS,), jnp.float32),
             jax.ShapeDtypeStruct((MAX_LEVELS,), jnp.float32))
    lowered = jax.jit(fn).lower(*specs)
    with open(os.path.join(outdir, "mac_tile.hlo.txt"), "w") as f:
        f.write(to_hlo_text(lowered))
    with open(os.path.join(outdir, "mac_tile_manifest.json"), "w") as f:
        json.dump({"m": m, "k": k, "n": n, "levels": MAX_LEVELS}, f)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts",
                    help="artifacts dir (a .hlo.txt path also works)")
    ap.add_argument("--skip-train", action="store_true")
    args = ap.parse_args()
    outdir = os.path.dirname(args.out) if args.out.endswith(".txt") \
        else args.out
    os.makedirs(outdir, exist_ok=True)

    need_train = not all(
        os.path.exists(os.path.join(outdir, f"{m}_weights.bin"))
        for m in MODELS)
    if need_train and not args.skip_train:
        from . import train
        train.main(outdir)

    for name, mod in MODELS.items():
        lower_model(name, mod, outdir)
    lower_mac_tile(outdir)
    print("AOT artifacts written to", outdir)


if __name__ == "__main__":
    main()
