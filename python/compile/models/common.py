"""Shared building blocks for the mini model zoo.

Two forward families:

* **training path** — float lax convolutions + BatchNorm with batch
  statistics (running stats tracked functionally in a ``state`` pytree).
* **inference path** — BN folded into per-layer matmul weights; every
  MAC layer goes through :func:`qmatmul`, which dispatches on the
  :class:`QuantCtx` mode:

  - ``float``     : plain matmul (the FP baseline "BL" of Fig. 5).
  - ``collect``   : plain matmul + records a deterministic activation
                    subsample and the crossbar-tile partial-sum absmax —
                    everything the Rust calibrator (Algorithm 1) needs.
  - ``fakequant`` : straight-through-estimator fake quantization (QAT /
                    fine-tuning path of Fig. 5).
  - ``quant``     : the deployed path — Pallas ``imc_mac_adc`` per-tile
                    conversion plus the layer's NL-ADC codebook, with
                    Gaussian conversion noise in LSB units (Fig. 6/7).
"""

from dataclasses import dataclass, field
from typing import Any

import jax
import jax.numpy as jnp

from ..kernels.imc_mac import imc_mac_adc
from ..kernels.nl_quant import nl_quantize
from ..kernels.ref import CROSSBAR_ROWS, min_ref_step, ref_nl_quantize

BN_EPS = 1e-5
BN_MOMENTUM = 0.9
#: activation samples recorded per quantized layer per collect batch
COLLECT_SAMPLES = 4096


# --------------------------------------------------------------------------
# Parameter initialisation
# --------------------------------------------------------------------------

def conv_init(key, kh, kw, cin, cout):
    fan_in = kh * kw * cin
    w = jax.random.normal(key, (kh, kw, cin, cout)) * jnp.sqrt(2.0 / fan_in)
    return {"w": w, "b": jnp.zeros(cout)}


def dense_init(key, din, dout):
    w = jax.random.normal(key, (din, dout)) * jnp.sqrt(2.0 / din)
    return {"w": w, "b": jnp.zeros(dout)}


def bn_init(c):
    return {"gamma": jnp.ones(c), "beta": jnp.zeros(c)}


def bn_state_init(c):
    return {"mean": jnp.zeros(c), "var": jnp.ones(c)}


# --------------------------------------------------------------------------
# Training-path ops
# --------------------------------------------------------------------------

def conv2d(x, w, stride=1, padding="SAME"):
    """NHWC x HWIO convolution."""
    return jax.lax.conv_general_dilated(
        x, w, (stride, stride), padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"))


def batchnorm(x, bn, state, train: bool):
    """Returns (y, new_state). Batch stats in training, running stats else."""
    if train:
        axes = tuple(range(x.ndim - 1))
        mean = jnp.mean(x, axis=axes)
        var = jnp.var(x, axis=axes)
        new_state = {
            "mean": BN_MOMENTUM * state["mean"] + (1 - BN_MOMENTUM) * mean,
            "var": BN_MOMENTUM * state["var"] + (1 - BN_MOMENTUM) * var,
        }
    else:
        mean, var = state["mean"], state["var"]
        new_state = state
    y = (x - mean) / jnp.sqrt(var + BN_EPS) * bn["gamma"] + bn["beta"]
    return y, new_state


def avg_pool(x, window=2, stride=2):
    return jax.lax.reduce_window(
        x, 0.0, jax.lax.add, (1, window, window, 1), (1, stride, stride, 1),
        "VALID") / float(window * window)


def max_pool(x, window=2, stride=2):
    return jax.lax.reduce_window(
        x, -jnp.inf, jax.lax.max, (1, window, window, 1),
        (1, stride, stride, 1), "VALID")


def global_avg_pool(x):
    return jnp.mean(x, axis=(1, 2))


def layer_norm(x, p):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + 1e-6) * p["gamma"] + p["beta"]


def fold_bn(w, b, bn, state):
    """Fold BN(gamma,beta,mean,var) into conv/dense weights (export time)."""
    std = jnp.sqrt(state["var"] + BN_EPS)
    scale = bn["gamma"] / std
    w_f = w * scale  # broadcasts over the last (cout) axis
    b_f = (b - state["mean"]) * scale + bn["beta"]
    return w_f, b_f


# --------------------------------------------------------------------------
# Inference pack: the tensors the Rust side owns at runtime
# --------------------------------------------------------------------------

@dataclass
class QLayerSpec:
    """Static metadata for one quantized MAC layer (goes to the manifest)."""

    name: str
    k: int           # contraction size (im2col'd for convs)
    n: int           # output features
    relu: bool       # ReLU'd (non-negative codebook) or signed


@dataclass
class InferencePack:
    """Folded weights + digital params; qweights order == QLayerSpec order."""

    qweights: list          # list of (wmat [K,N], bias [N])
    qspecs: list            # list of QLayerSpec
    digital: dict           # embeddings / layernorms / other digital params


# --------------------------------------------------------------------------
# QuantCtx: mode dispatch for the unified inference graph
# --------------------------------------------------------------------------

@dataclass
class QuantCtx:
    mode: str = "float"     # float | collect | fakequant | quant
    # quant mode: stacked padded codebooks, [nq, 128] each
    nl_refs: Any = None
    nl_centers: Any = None
    tile_refs: Any = None
    tile_centers: Any = None
    noise_std: Any = 0.0    # sigma in ADC-LSB units (Fig. 7 noise model)
    key: Any = None         # PRNG key for conversion noise
    # fakequant mode: python list of (refs, centers) per quantized layer
    fq_codebooks: Any = None
    interpret: bool = True
    qi: int = 0             # running quantized-layer index
    records: list = field(default_factory=list)   # collect: subsamples
    tile_maxes: list = field(default_factory=list)  # collect: partial absmax

    def next_key(self):
        self.key, sub = jax.random.split(self.key)
        return sub


def _collect_subsample(y):
    """Deterministic evenly-spaced subsample of a layer's activations.

    The index formula ``i * len // want`` matches the native backend's
    ``collect_subsample`` exactly: it spans the whole activation —
    including the tail that the old truncated-stride decimation silently
    dropped — and wraps tiny layers by repeating indices.
    """
    flat = y.reshape(-1)
    idx = (jnp.arange(COLLECT_SAMPLES) * flat.shape[0]) // COLLECT_SAMPLES
    return flat[idx]


def _tile_absmax(x2d, w):
    """Max |tile partial sum| over 256-row crossbar tiles (collect mode)."""
    k = x2d.shape[1]
    kt = -(-k // CROSSBAR_ROWS)
    m = jnp.float32(0.0)
    for t in range(kt):
        lo, hi = t * CROSSBAR_ROWS, min((t + 1) * CROSSBAR_ROWS, k)
        m = jnp.maximum(m, jnp.max(jnp.abs(x2d[:, lo:hi] @ w[lo:hi, :])))
    return m


def qmatmul(ctx: QuantCtx, x2d, wmat, bias, relu: bool):
    """One quantized MAC layer on 2-D operands; dispatches on ctx.mode."""
    if ctx.mode == "quant":
        qi = ctx.qi
        t_refs, t_centers = ctx.tile_refs[qi], ctx.tile_centers[qi]
        n_refs, n_centers = ctx.nl_refs[qi], ctx.nl_centers[qi]
        m, k = x2d.shape
        n = wmat.shape[1]
        kt = -(-k // CROSSBAR_ROWS)
        tile_noise = (
            jax.random.normal(ctx.next_key(), (kt, m, n))
            * ctx.noise_std * min_ref_step(t_refs)
        )
        mac = imc_mac_adc(x2d, wmat, t_refs, t_centers, tile_noise,
                          interpret=ctx.interpret)
        y = mac + bias
        if relu:
            y = jnp.maximum(y, 0.0)
        out_noise = (
            jax.random.normal(ctx.next_key(), y.shape)
            * ctx.noise_std * min_ref_step(n_refs)
        )
        y = nl_quantize(y + out_noise, n_refs, n_centers,
                        interpret=ctx.interpret)
    else:
        y = x2d @ wmat + bias
        if relu:
            y = jnp.maximum(y, 0.0)
        if ctx.mode == "collect":
            ctx.records.append(_collect_subsample(y))
            ctx.tile_maxes.append(_tile_absmax(x2d, wmat))
        elif ctx.mode == "fakequant":
            refs, centers = ctx.fq_codebooks[ctx.qi]
            q = ref_nl_quantize(y, refs, centers)
            y = y + jax.lax.stop_gradient(q - y)  # STE
    ctx.qi += 1
    return y


def im2col(x, kh, kw, stride=1, padding="SAME"):
    """Manual im2col with (kh, kw, cin) feature ordering — matches
    ``w.reshape(kh*kw*cin, cout)`` for HWIO conv weights."""
    b, h, w_, c = x.shape
    if padding == "SAME":
        oh, ow = -(-h // stride), -(-w_ // stride)
        ph = max(0, (oh - 1) * stride + kh - h)
        pw = max(0, (ow - 1) * stride + kw - w_)
        x = jnp.pad(x, ((0, 0), (ph // 2, ph - ph // 2),
                        (pw // 2, pw - pw // 2), (0, 0)))
    else:
        oh, ow = (h - kh) // stride + 1, (w_ - kw) // stride + 1
    cols = []
    for i in range(kh):
        for j in range(kw):
            patch = x[:, i:i + stride * oh:stride, j:j + stride * ow:stride, :]
            cols.append(patch)
    return jnp.concatenate(cols, axis=-1).reshape(b * oh * ow, kh * kw * c), (b, oh, ow)


def qconv(ctx: QuantCtx, x, wmat, bias, kh, kw, stride=1, relu=True,
          padding="SAME"):
    """Quantized convolution = im2col + :func:`qmatmul` (the IMC mapping)."""
    x2d, (b, oh, ow) = im2col(x, kh, kw, stride, padding)
    y = qmatmul(ctx, x2d, wmat, bias, relu)
    return y.reshape(b, oh, ow, -1)
