"""Mini VGG (stand-in for the paper's VGG-16 on CIFAR-100).

Plain Conv-BN-ReLU stacks with max-pool downsampling and a two-layer
classifier head — the VGG signature.  20 synthetic classes echo the
finer-grained CIFAR-100 task.

Quantized MAC layers (7): conv1..conv5, fc1, fc2.
"""

import jax
import jax.numpy as jnp

from . import common as cm

NAME = "vgg"
INPUT_SHAPE = (16, 16, 3)
NUM_CLASSES = 20
SEQUENCE = False

_CFG = [  # (name, cin, cout, pool-after)
    ("conv1", 3, 16, False),
    ("conv2", 16, 16, True),
    ("conv3", 16, 32, False),
    ("conv4", 32, 32, True),
    ("conv5", 32, 48, True),
]
_FLAT = 2 * 2 * 48  # 16 -> 8 -> 4 -> 2 spatial


def init_params(key):
    ks = jax.random.split(key, len(_CFG) + 2)
    p = {}
    for i, (name, cin, cout, _) in enumerate(_CFG):
        p[name] = cm.conv_init(ks[i], 3, 3, cin, cout)
        p["bn_" + name] = cm.bn_init(cout)
    p["fc1"] = cm.dense_init(ks[-2], _FLAT, 64)
    p["fc2"] = cm.dense_init(ks[-1], 64, NUM_CLASSES)
    return p


def init_state():
    return {"bn_" + name: cm.bn_state_init(cout)
            for name, _, cout, _ in _CFG}


def forward_train(params, state, x, train: bool):
    ns = {}
    y = x
    for name, _, _, pool in _CFG:
        y = cm.conv2d(y, params[name]["w"]) + params[name]["b"]
        y, ns["bn_" + name] = cm.batchnorm(
            y, params["bn_" + name], state["bn_" + name], train)
        y = jnp.maximum(y, 0.0)
        if pool:
            y = cm.max_pool(y)
    y = y.reshape(y.shape[0], -1)
    y = jnp.maximum(y @ params["fc1"]["w"] + params["fc1"]["b"], 0.0)
    logits = y @ params["fc2"]["w"] + params["fc2"]["b"]
    return logits, ns


def export_pack(params, state):
    qweights, qspecs = [], []
    for name, cin, cout, _ in _CFG:
        w, b = cm.fold_bn(params[name]["w"], params[name]["b"],
                          params["bn_" + name], state["bn_" + name])
        qweights.append((w.reshape(9 * cin, cout), b))
        qspecs.append(cm.QLayerSpec(name, 9 * cin, cout, True))
    qweights.append((params["fc1"]["w"], params["fc1"]["b"]))
    qspecs.append(cm.QLayerSpec("fc1", _FLAT, 64, True))
    qweights.append((params["fc2"]["w"], params["fc2"]["b"]))
    qspecs.append(cm.QLayerSpec("fc2", 64, NUM_CLASSES, False))
    return cm.InferencePack(qweights, qspecs, digital={})


def forward_infer(pack, x, ctx):
    qw = pack.qweights
    y = x
    for i, (_, _, _, pool) in enumerate(_CFG):
        y = cm.qconv(ctx, y, qw[i][0], qw[i][1], 3, 3, 1, True)
        if pool:
            y = cm.max_pool(y)
    y = y.reshape(y.shape[0], -1)
    y = cm.qmatmul(ctx, y, qw[5][0], qw[5][1], relu=True)
    return cm.qmatmul(ctx, y, qw[6][0], qw[6][1], relu=False)
