"""Mini DistilBERT (stand-in for the paper's DistilBERT on SQuAD).

A two-layer post-LN transformer encoder with multi-head attention — the
quantized MACs are exactly the projections the paper quantizes, including
the first attention layer's query projection Q = WX whose distribution
Fig. 4 studies (signed, roughly symmetric, heavy-tailed).  SQuAD span
extraction is replaced by synthetic sequence classification (DESIGN.md §5);
the quantization-relevant tensors are the same.

Quantized MAC layers (13): 2 x (q, k, v, o, ff1, ff2), cls.
"""

import jax
import jax.numpy as jnp

from . import common as cm

NAME = "distilbert"
VOCAB = 64
SEQ_LEN = 32
D_MODEL = 48
N_HEADS = 4
D_FF = 96
N_LAYERS = 2
NUM_CLASSES = 6
INPUT_SHAPE = (SEQ_LEN,)
SEQUENCE = True

_HD = D_MODEL // N_HEADS


def init_params(key):
    ks = jax.random.split(key, 4 + N_LAYERS * 6)
    p = {
        "embed": jax.random.normal(ks[0], (VOCAB, D_MODEL)) * 0.05,
        "pos": jax.random.normal(ks[1], (SEQ_LEN, D_MODEL)) * 0.05,
        "cls": cm.dense_init(ks[2], D_MODEL, NUM_CLASSES),
    }
    kidx = 3
    for l in range(N_LAYERS):
        for proj, dout in (("q", D_MODEL), ("k", D_MODEL), ("v", D_MODEL),
                           ("o", D_MODEL), ("ff1", D_FF), ("ff2", D_MODEL)):
            din = D_FF if proj == "ff2" else D_MODEL
            p[f"l{l}_{proj}"] = cm.dense_init(ks[kidx], din, dout)
            kidx += 1
        p[f"l{l}_ln1"] = {"gamma": jnp.ones(D_MODEL), "beta": jnp.zeros(D_MODEL)}
        p[f"l{l}_ln2"] = {"gamma": jnp.ones(D_MODEL), "beta": jnp.zeros(D_MODEL)}
    return p


def init_state():
    return {}  # no BatchNorm in the transformer


def _attention(q, k, v, b, t):
    """Digital-domain attention over quantized Q/K/V (B*T rows)."""
    def heads(x):
        return x.reshape(b, t, N_HEADS, _HD).transpose(0, 2, 1, 3)

    qh, kh, vh = heads(q), heads(k), heads(v)
    scores = qh @ kh.transpose(0, 1, 3, 2) / jnp.sqrt(float(_HD))
    attn = jax.nn.softmax(scores, axis=-1)
    out = (attn @ vh).transpose(0, 2, 1, 3).reshape(b * t, D_MODEL)
    return out


def _forward(get_w, params_digital, x_tokens, matmul):
    """Shared forward; ``matmul(idx, x2d, relu)`` consumes qlayers in order."""
    b, t = x_tokens.shape
    h = params_digital["embed"][x_tokens] + params_digital["pos"][None, :, :]
    h = h.reshape(b * t, D_MODEL)
    wi = 0
    for l in range(N_LAYERS):
        q = matmul(wi, h, False)
        k = matmul(wi + 1, h, False)
        v = matmul(wi + 2, h, False)
        a = _attention(q, k, v, b, t)
        o = matmul(wi + 3, a, False)
        h = cm.layer_norm(h + o, params_digital[f"l{l}_ln1"])
        f = matmul(wi + 4, h, True)       # GeLU -> ReLU (IMC-digital friendly)
        f = matmul(wi + 5, f, False)
        h = cm.layer_norm(h + f, params_digital[f"l{l}_ln2"])
        wi += 6
    pooled = h.reshape(b, t, D_MODEL).mean(axis=1)
    return matmul(wi, pooled, False)


def forward_train(params, state, x_tokens, train: bool):
    def matmul(i, x2d, relu):
        name = _qlayer_names()[i]
        y = x2d @ params[name]["w"] + params[name]["b"]
        return jnp.maximum(y, 0.0) if relu else y

    return _forward(None, params, x_tokens, matmul), {}


def _qlayer_names():
    names = []
    for l in range(N_LAYERS):
        names += [f"l{l}_{p}" for p in ("q", "k", "v", "o", "ff1", "ff2")]
    return names + ["cls"]


def export_pack(params, state):
    qweights, qspecs = [], []
    for name in _qlayer_names():
        w, b = params[name]["w"], params[name]["b"]
        qweights.append((w, b))
        relu = name.endswith("ff1")
        qspecs.append(cm.QLayerSpec(name, w.shape[0], w.shape[1], relu))
    digital = {"embed": params["embed"], "pos": params["pos"]}
    for l in range(N_LAYERS):
        digital[f"l{l}_ln1"] = params[f"l{l}_ln1"]
        digital[f"l{l}_ln2"] = params[f"l{l}_ln2"]
    return cm.InferencePack(qweights, qspecs, digital=digital)


def forward_infer(pack, x_tokens, ctx):
    def matmul(i, x2d, relu):
        return cm.qmatmul(ctx, x2d, pack.qweights[i][0], pack.qweights[i][1],
                          relu)

    return _forward(None, pack.digital, x_tokens, matmul)
