"""Mini ResNet (stand-in for the paper's ResNet-18 on CIFAR-10).

Same structural elements as ResNet-18 — a Conv-BN-ReLU stem (the layer
Fig. 1 calibrates on), residual basic blocks with a strided 1x1 projection
shortcut, global average pooling and a linear classifier — scaled to a
16x16x3 synthetic 10-class dataset so it trains on this CPU testbed.

Quantized MAC layers (7): conv0, b1c1, b1c2, b2c1, b2c2, b2sc, fc.
"""

import jax
import jax.numpy as jnp

from . import common as cm

NAME = "resnet"
INPUT_SHAPE = (16, 16, 3)
NUM_CLASSES = 10
SEQUENCE = False


def init_params(key):
    ks = jax.random.split(key, 7)
    return {
        "conv0": cm.conv_init(ks[0], 3, 3, 3, 16), "bn0": cm.bn_init(16),
        "b1c1": cm.conv_init(ks[1], 3, 3, 16, 16), "bn11": cm.bn_init(16),
        "b1c2": cm.conv_init(ks[2], 3, 3, 16, 16), "bn12": cm.bn_init(16),
        "b2c1": cm.conv_init(ks[3], 3, 3, 16, 32), "bn21": cm.bn_init(32),
        "b2c2": cm.conv_init(ks[4], 3, 3, 32, 32), "bn22": cm.bn_init(32),
        "b2sc": cm.conv_init(ks[5], 1, 1, 16, 32), "bnsc": cm.bn_init(32),
        "fc": cm.dense_init(ks[6], 32, NUM_CLASSES),
    }


def init_state():
    return {"bn0": cm.bn_state_init(16), "bn11": cm.bn_state_init(16),
            "bn12": cm.bn_state_init(16), "bn21": cm.bn_state_init(32),
            "bn22": cm.bn_state_init(32), "bnsc": cm.bn_state_init(32)}


def forward_train(params, state, x, train: bool):
    ns = {}

    def cbr(name, bn, x, stride=1, relu=True):
        y = cm.conv2d(x, params[name]["w"], stride) + params[name]["b"]
        y, ns[bn] = cm.batchnorm(y, params[bn], state[bn], train)
        return jnp.maximum(y, 0.0) if relu else y

    y = cbr("conv0", "bn0", x)
    h = cbr("b1c1", "bn11", y)
    h = cbr("b1c2", "bn12", h, relu=False)
    y = jnp.maximum(y + h, 0.0)
    h = cbr("b2c1", "bn21", y, stride=2)
    h = cbr("b2c2", "bn22", h, relu=False)
    sc = cbr("b2sc", "bnsc", y, stride=2, relu=False)
    y = jnp.maximum(h + sc, 0.0)
    y = cm.global_avg_pool(y)
    logits = y @ params["fc"]["w"] + params["fc"]["b"]
    return logits, ns


_CONVS = [  # (param, bn, kh, kw, stride, relu-in-codebook)
    ("conv0", "bn0", 3, 3, 1, True),
    ("b1c1", "bn11", 3, 3, 1, True),
    ("b1c2", "bn12", 3, 3, 1, False),
    ("b2c1", "bn21", 3, 3, 2, True),
    ("b2c2", "bn22", 3, 3, 1, False),
    ("b2sc", "bnsc", 1, 1, 2, False),
]


def export_pack(params, state):
    qweights, qspecs = [], []
    for name, bn, kh, kw, _s, relu in _CONVS:
        w, b = cm.fold_bn(params[name]["w"], params[name]["b"],
                          params[bn], state[bn])
        cin, cout = w.shape[2], w.shape[3]
        qweights.append((w.reshape(kh * kw * cin, cout), b))
        qspecs.append(cm.QLayerSpec(name, kh * kw * cin, cout, relu))
    qweights.append((params["fc"]["w"], params["fc"]["b"]))
    qspecs.append(cm.QLayerSpec("fc", 32, NUM_CLASSES, False))
    return cm.InferencePack(qweights, qspecs, digital={})


def forward_infer(pack, x, ctx):
    qw = pack.qweights

    def conv(i, x, stride, relu, kh=3, kw=3):
        return cm.qconv(ctx, x, qw[i][0], qw[i][1], kh, kw, stride, relu)

    y = conv(0, x, 1, True)
    h = conv(1, y, 1, True)
    h = conv(2, h, 1, False)
    y = jnp.maximum(y + h, 0.0)           # digital residual add + ReLU
    h = conv(3, y, 2, True)
    h = conv(4, h, 1, False)
    sc = conv(5, y, 2, False, kh=1, kw=1)
    y = jnp.maximum(h + sc, 0.0)
    y = cm.global_avg_pool(y)
    return cm.qmatmul(ctx, y, qw[6][0], qw[6][1], relu=False)
