"""Mini model zoo (L2): the paper's four evaluation topologies.

Each model module exposes:

* ``init_params(key)`` / ``init_state()`` — training-time parameters and
  BatchNorm running statistics.
* ``forward_train(params, state, x, train)`` — float forward used by
  ``train.py`` (lax convolutions, batch statistics).
* ``export_pack(params, state)`` — folds BN into per-layer ``(K, N)``
  matmul weights and returns an :class:`~compile.models.common.InferencePack`
  (the exact tensors the Rust runtime feeds the AOT graphs).
* ``forward_infer(pack, x, ctx)`` — the unified inference graph lowered to
  HLO: float / collect / fake-quant / quant modes via
  :class:`~compile.models.common.QuantCtx`.

DESIGN.md §5 documents why these minis stand in for the paper's
ResNet-18 / VGG-16 / Inception-V3 / DistilBERT.
"""

from . import common, distilbert_mini, inception_mini, resnet_mini, vgg_mini

MODELS = {
    "resnet": resnet_mini,
    "vgg": vgg_mini,
    "inception": inception_mini,
    "distilbert": distilbert_mini,
}

__all__ = ["common", "MODELS", "resnet_mini", "vgg_mini", "inception_mini",
           "distilbert_mini"]
