"""Mini Inception (stand-in for the paper's Inception-V3 on Tiny-ImageNet).

The Inception signature: parallel branches of different receptive fields
(1x1, 1x1->3x3, pool->1x1 projection) concatenated along channels, stacked
twice over a Conv-BN-ReLU stem.  Its depth (longest path crosses more
quantized layers than the other CNNs) is what makes Inception-V3 the most
noise-sensitive model in Fig. 6 — the property the mini preserves.

Quantized MAC layers (10): stem, 2 x (b0, b1a, b1b, pp), fc.
"""

import jax
import jax.numpy as jnp

from . import common as cm

NAME = "inception"
INPUT_SHAPE = (16, 16, 3)
NUM_CLASSES = 10
SEQUENCE = False

_B0, _B1R, _B1, _PP = 8, 8, 12, 8
_OUT = _B0 + _B1 + _PP  # 28 channels per inception block


def _block_names(i):
    return [f"i{i}_b0", f"i{i}_b1a", f"i{i}_b1b", f"i{i}_pp"]


def init_params(key):
    ks = jax.random.split(key, 11)
    p = {"stem": cm.conv_init(ks[0], 3, 3, 3, 16), "bn_stem": cm.bn_init(16)}
    kidx = 1
    for i, cin in ((1, 16), (2, _OUT)):
        b0, b1a, b1b, pp = _block_names(i)
        p[b0] = cm.conv_init(ks[kidx], 1, 1, cin, _B0)
        p[b1a] = cm.conv_init(ks[kidx + 1], 1, 1, cin, _B1R)
        p[b1b] = cm.conv_init(ks[kidx + 2], 3, 3, _B1R, _B1)
        p[pp] = cm.conv_init(ks[kidx + 3], 1, 1, cin, _PP)
        for name, c in ((b0, _B0), (b1a, _B1R), (b1b, _B1), (pp, _PP)):
            p["bn_" + name] = cm.bn_init(c)
        kidx += 4
    p["fc"] = cm.dense_init(ks[kidx], _OUT, NUM_CLASSES)
    return p


def init_state():
    st = {"bn_stem": cm.bn_state_init(16)}
    for i in (1, 2):
        b0, b1a, b1b, pp = _block_names(i)
        for name, c in ((b0, _B0), (b1a, _B1R), (b1b, _B1), (pp, _PP)):
            st["bn_" + name] = cm.bn_state_init(c)
    return st


def _pool3(x):
    """3x3 stride-1 SAME average pool (the inception pool branch)."""
    return jax.lax.reduce_window(
        x, 0.0, jax.lax.add, (1, 3, 3, 1), (1, 1, 1, 1), "SAME") / 9.0


def forward_train(params, state, x, train: bool):
    ns = {}

    def cbr(name, x):
        y = cm.conv2d(x, params[name]["w"]) + params[name]["b"]
        y, ns["bn_" + name] = cm.batchnorm(
            y, params["bn_" + name], state["bn_" + name], train)
        return jnp.maximum(y, 0.0)

    y = cm.max_pool(cbr("stem", x))
    for i in (1, 2):
        b0, b1a, b1b, pp = _block_names(i)
        br0 = cbr(b0, y)
        br1 = cbr(b1b, cbr(b1a, y))
        br2 = cbr(pp, _pool3(y))
        y = jnp.concatenate([br0, br1, br2], axis=-1)
    y = cm.global_avg_pool(y)
    logits = y @ params["fc"]["w"] + params["fc"]["b"]
    return logits, ns


def _conv_list():
    lst = [("stem", 3, 16, 3)]
    for i, cin in ((1, 16), (2, _OUT)):
        b0, b1a, b1b, pp = _block_names(i)
        lst += [(b0, cin, _B0, 1), (b1a, cin, _B1R, 1),
                (b1b, _B1R, _B1, 3), (pp, cin, _PP, 1)]
    return lst


def export_pack(params, state):
    qweights, qspecs = [], []
    for name, cin, cout, ksz in _conv_list():
        w, b = cm.fold_bn(params[name]["w"], params[name]["b"],
                          params["bn_" + name], state["bn_" + name])
        qweights.append((w.reshape(ksz * ksz * cin, cout), b))
        qspecs.append(cm.QLayerSpec(name, ksz * ksz * cin, cout, True))
    qweights.append((params["fc"]["w"], params["fc"]["b"]))
    qspecs.append(cm.QLayerSpec("fc", _OUT, NUM_CLASSES, False))
    return cm.InferencePack(qweights, qspecs, digital={})


def forward_infer(pack, x, ctx):
    qw = pack.qweights

    def conv(i, x, ksz):
        return cm.qconv(ctx, x, qw[i][0], qw[i][1], ksz, ksz, 1, True)

    y = cm.max_pool(conv(0, x, 3))
    wi = 1
    for _ in (1, 2):
        br0 = conv(wi, y, 1)
        br1 = conv(wi + 2, conv(wi + 1, y, 1), 3)
        br2 = conv(wi + 3, _pool3(y), 1)
        y = jnp.concatenate([br0, br1, br2], axis=-1)
        wi += 4
    y = cm.global_avg_pool(y)
    return cm.qmatmul(ctx, y, qw[wi][0], qw[wi][1], relu=False)
