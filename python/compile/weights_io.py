"""Flat tensor container: python writer <-> rust reader (rust/src/io).

Binary layout (little-endian):

    magic  u32 = 0x42534B51  ("BSKQ")
    version u32 = 1
    count  u32
    per tensor:
        name_len u32, name utf-8 bytes
        ndim u32, dims u32 * ndim
        f32 data (prod(dims) elements)

Purpose-built so the Rust runtime owns the trained weights at request time
without a numpy/npz dependency on either side.
"""

import struct

import numpy as np

MAGIC = 0x42534B51
VERSION = 1


def save_tensors(path: str, tensors: list[tuple[str, np.ndarray]]) -> None:
    with open(path, "wb") as f:
        f.write(struct.pack("<III", MAGIC, VERSION, len(tensors)))
        for name, arr in tensors:
            arr = np.ascontiguousarray(arr, dtype=np.float32)
            nb = name.encode("utf-8")
            f.write(struct.pack("<I", len(nb)))
            f.write(nb)
            f.write(struct.pack("<I", arr.ndim))
            f.write(struct.pack(f"<{arr.ndim}I", *arr.shape))
            f.write(arr.tobytes())


def load_tensors(path: str) -> list[tuple[str, np.ndarray]]:
    out = []
    with open(path, "rb") as f:
        magic, version, count = struct.unpack("<III", f.read(12))
        if magic != MAGIC or version != VERSION:
            raise ValueError(f"bad container header: {magic:#x} v{version}")
        for _ in range(count):
            (nlen,) = struct.unpack("<I", f.read(4))
            name = f.read(nlen).decode("utf-8")
            (ndim,) = struct.unpack("<I", f.read(4))
            dims = struct.unpack(f"<{ndim}I", f.read(4 * ndim))
            n = int(np.prod(dims)) if ndim else 1
            arr = np.frombuffer(f.read(4 * n), dtype="<f4").reshape(dims)
            out.append((name, arr.copy()))
    return out
