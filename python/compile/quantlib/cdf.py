"""CDF (equal-probability) quantizer baseline [11].

Centers sit at the mid-probability quantiles so each quantization cell
carries equal probability mass.  On ReLU activations the huge zero spike
collapses many quantiles onto the same value — the degeneracy the paper
points out ("highly sensitive to distribution outliers"); duplicated
centers are nudged apart only enough to keep references strictly sorted,
so the effective number of distinct levels drops, which is exactly the
failure mode Fig. 1 exhibits.
"""

import numpy as np


def fit_cdf(samples: np.ndarray, bits: int) -> np.ndarray:
    """``2**bits`` equal-probability-mass centers (mid-cell quantiles)."""
    if bits < 1 or bits > 7:
        raise ValueError(f"bits must be in [1, 7], got {bits}")
    samples = np.asarray(samples, dtype=np.float64).ravel()
    if samples.size == 0:
        raise ValueError("cannot fit on empty sample set")
    k = 2 ** bits
    qs = (np.arange(k) + 0.5) / k
    centers = np.quantile(samples, qs)
    # Keep the codebook weakly increasing but avoid zero-width cells in the
    # reference ladder: spread exact duplicates by a tiny epsilon.
    eps = 1e-12 + 1e-9 * max(1.0, float(np.abs(samples).max()))
    for i in range(1, k):
        if centers[i] <= centers[i - 1]:
            centers[i] = centers[i - 1] + eps
    return centers
