"""Quantizer suite for BS-KMQ reproduction (build-time Python side).

Each quantizer exposes ``fit(samples, bits, **kw) -> centers`` returning a
sorted 1-D numpy array of ``2**bits`` quantization centers.  Centers are
converted to floor-ADC reference levels via :func:`codebook.refs_from_centers`
(Eq. 2 of the paper) and applied with :func:`codebook.quantize_np` /
:func:`codebook.quantize_jnp`.

The Rust layer (``rust/src/quant``) mirrors these implementations; the pytest
suite cross-checks the two through golden vectors.
"""

from .codebook import (
    MAX_LEVELS,
    Codebook,
    cell_budget,
    mse,
    pad_codebook,
    project_to_hardware,
    quantize_jnp,
    quantize_np,
    refs_from_centers,
)
from .linear import fit_linear
from .lloyd_max import fit_lloyd_max
from .cdf import fit_cdf
from .kmeans import fit_kmeans, kmeans_1d
from .bs_kmq import BSKMQCalibrator, fit_bs_kmq

FITTERS = {
    "linear": fit_linear,
    "lloyd_max": fit_lloyd_max,
    "cdf": fit_cdf,
    "kmeans": fit_kmeans,
    "bs_kmq": fit_bs_kmq,
}

__all__ = [
    "MAX_LEVELS",
    "Codebook",
    "cell_budget",
    "project_to_hardware",
    "mse",
    "pad_codebook",
    "quantize_jnp",
    "quantize_np",
    "refs_from_centers",
    "fit_linear",
    "fit_lloyd_max",
    "fit_cdf",
    "fit_kmeans",
    "kmeans_1d",
    "fit_bs_kmq",
    "BSKMQCalibrator",
    "FITTERS",
]
