"""Linear (uniform min-max) quantizer — the paper's baseline [14].

Centers are evenly spaced over the observed activation range, matching the
linear in-memory ramp ADC of Yang et al. (DAC'25): equal reference steps,
no adaptation to the activation distribution.
"""

import numpy as np


def fit_linear(samples: np.ndarray, bits: int, lo: float | None = None,
               hi: float | None = None) -> np.ndarray:
    """Evenly spaced ``2**bits`` centers over ``[lo, hi]`` (default min/max)."""
    if bits < 1 or bits > 7:
        raise ValueError(f"bits must be in [1, 7], got {bits}")
    samples = np.asarray(samples, dtype=np.float64).ravel()
    if samples.size == 0:
        raise ValueError("cannot fit on empty sample set")
    lo = float(samples.min()) if lo is None else float(lo)
    hi = float(samples.max()) if hi is None else float(hi)
    if hi <= lo:
        hi = lo + 1e-8
    return np.linspace(lo, hi, 2 ** bits)
