"""Lloyd-Max quantizer baseline [2].

Classic density-based alternating optimization on a histogram approximation
of the activation pdf: decision boundaries move to midpoints of adjacent
centroids, centroids move to the conditional mean of their cell.  The
histogram approximation (rather than exact sample k-means) matches how
Lloyd-Max is deployed in the RRAM CNN literature the paper cites, and gives
it the characteristic sensitivity to long tails: empty outer cells keep
their centroids pinned to the tail region.
"""

import numpy as np

_DEFAULT_BINS = 512


def fit_lloyd_max(samples: np.ndarray, bits: int, iters: int = 60,
                  bins: int = _DEFAULT_BINS, tol: float = 1e-9) -> np.ndarray:
    """Fit ``2**bits`` Lloyd-Max centroids on a histogram density estimate."""
    if bits < 1 or bits > 7:
        raise ValueError(f"bits must be in [1, 7], got {bits}")
    samples = np.asarray(samples, dtype=np.float64).ravel()
    if samples.size == 0:
        raise ValueError("cannot fit on empty sample set")
    k = 2 ** bits
    lo, hi = float(samples.min()), float(samples.max())
    if hi <= lo:
        return np.full(k, lo)

    hist, edges = np.histogram(samples, bins=bins, range=(lo, hi))
    mids = 0.5 * (edges[:-1] + edges[1:])
    w = hist.astype(np.float64)
    wx = w * mids

    centers = np.linspace(lo, hi, k)  # uniform init, per the classic recipe
    for _ in range(iters):
        bounds = 0.5 * (centers[:-1] + centers[1:])
        cell = np.searchsorted(bounds, mids, side="right")
        new = centers.copy()
        for i in range(k):
            m = cell == i
            wi = w[m].sum()
            if wi > 0:
                new[i] = wx[m].sum() / wi
        if np.max(np.abs(new - centers)) < tol:
            centers = new
            break
        centers = new
    return np.sort(centers)
