"""Standard 1-D k-means quantizer baseline [13] (built from scratch).

k-means++ seeding plus Lloyd iterations over (a subsample of) the raw,
untrimmed activation samples.  This is the "standard K-means" the paper
compares against: no tail trimming and no boundary suppression, so the
ReLU zero spike and clamping tails pull centroids toward the distribution
edges ("boundary instability") — the behaviour BS-KMQ fixes.
"""

import numpy as np

_MAX_FIT_SAMPLES = 20_000


def _kmeanspp_init(x: np.ndarray, k: int, rng: np.random.Generator) -> np.ndarray:
    centers = np.empty(k, dtype=np.float64)
    centers[0] = x[rng.integers(x.size)]
    d2 = (x - centers[0]) ** 2
    for i in range(1, k):
        total = d2.sum()
        if total <= 0:
            centers[i:] = x[rng.integers(x.size, size=k - i)]
            break
        probs = d2 / total
        centers[i] = x[rng.choice(x.size, p=probs)]
        d2 = np.minimum(d2, (x - centers[i]) ** 2)
    return np.sort(centers)


def kmeans_1d(x: np.ndarray, k: int, iters: int = 50, seed: int = 0,
              tol: float = 1e-10) -> np.ndarray:
    """Lloyd's algorithm in 1-D; sorted centroids enable O(n log k) assign."""
    x = np.asarray(x, dtype=np.float64).ravel()
    if x.size == 0:
        raise ValueError("kmeans on empty sample set")
    rng = np.random.default_rng(seed)
    if x.size > _MAX_FIT_SAMPLES:
        x = rng.choice(x, _MAX_FIT_SAMPLES, replace=False)
    k = min(k, max(1, np.unique(x).size))
    centers = _kmeanspp_init(x, k, rng)
    for _ in range(iters):
        bounds = 0.5 * (centers[:-1] + centers[1:])
        cell = np.searchsorted(bounds, x, side="right")
        sums = np.bincount(cell, weights=x, minlength=k)
        counts = np.bincount(cell, minlength=k)
        new = centers.copy()
        nz = counts > 0
        new[nz] = sums[nz] / counts[nz]
        new = np.sort(new)
        if np.max(np.abs(new - centers)) < tol:
            centers = new
            break
        centers = new
    return centers


def fit_kmeans(samples: np.ndarray, bits: int, iters: int = 50,
               seed: int = 0) -> np.ndarray:
    """``2**bits`` standard k-means centers over the raw sample set."""
    if bits < 1 or bits > 7:
        raise ValueError(f"bits must be in [1, 7], got {bits}")
    k = 2 ** bits
    centers = kmeans_1d(samples, k, iters=iters, seed=seed)
    if centers.size < k:  # degenerate data: repeat the last center
        centers = np.concatenate([centers, np.full(k - centers.size, centers[-1])])
    return centers
