"""Boundary Suppressed K-Means Quantization (BS-KMQ) — paper Algorithm 1.

Two stages:

Stage 1 (robust statistical calibration): stream calibration batches; per
batch drop the extreme ``alpha`` tails on both sides, track the trimmed
batch min/max, and fold them into a global range ``[g_min, g_max]`` with an
exponential moving average (Eq. 1, decay 0.9/0.1).

Stage 2 (boundary-suppressed clustering): clamp all retained samples into
``[g_min, g_max]``, *remove* the samples that saturate at either bound
(the ReLU zero spike and the clamp pile-up), k-means the interior into
``2**b - 2`` centers, and re-attach ``g_min``/``g_max`` as the outermost
centers so the codebook still covers the full hardware range.
"""

import numpy as np

from .kmeans import kmeans_1d

DEFAULT_ALPHA = 0.005
EMA_KEEP = 0.9
EMA_NEW = 0.1


class BSKMQCalibrator:
    """Streaming implementation of Algorithm 1 (mirrors rust/src/quant/bs_kmq.rs)."""

    def __init__(self, alpha: float = DEFAULT_ALPHA, max_buffer: int = 200_000,
                 seed: int = 0):
        if not 0.0 <= alpha < 0.5:
            raise ValueError(f"alpha must be in [0, 0.5), got {alpha}")
        self.alpha = alpha
        self.g_min: float | None = None
        self.g_max: float | None = None
        self._buffer: list[np.ndarray] = []
        self._buffered = 0
        self._max_buffer = max_buffer
        self._rng = np.random.default_rng(seed)
        self.batches_seen = 0

    def observe(self, batch: np.ndarray) -> None:
        """Algorithm 1 lines 5-17: trim tails, EMA the range, buffer interior."""
        a = np.asarray(batch, dtype=np.float64).ravel()
        if a.size == 0:
            return
        p_low, p_high = np.quantile(a, [self.alpha, 1.0 - self.alpha])
        cent = a[(a >= p_low) & (a <= p_high)]
        if cent.size == 0:
            cent = a
        b_min, b_max = float(cent.min()), float(cent.max())
        if self.g_min is None:
            self.g_min, self.g_max = b_min, b_max
        else:
            self.g_min = EMA_KEEP * self.g_min + EMA_NEW * b_min
            self.g_max = EMA_KEEP * self.g_max + EMA_NEW * b_max
        self.batches_seen += 1
        # Reservoir-ish buffering keeps calibration memory bounded.
        if self._buffered + cent.size > self._max_buffer:
            keep = max(0, self._max_buffer - self._buffered)
            if keep == 0:
                return
            cent = self._rng.choice(cent, keep, replace=False)
        self._buffer.append(cent)
        self._buffered += cent.size

    def finish(self, bits: int, iters: int = 50, seed: int = 0) -> np.ndarray:
        """Algorithm 1 lines 18-23: boundary-suppressed clustering."""
        if bits < 1 or bits > 7:
            raise ValueError(f"bits must be in [1, 7], got {bits}")
        if self.g_min is None or not self._buffer:
            raise RuntimeError("finish() before any observe()")
        g_min, g_max = float(self.g_min), float(self.g_max)
        if g_max <= g_min:
            g_max = g_min + 1e-8
        s = np.concatenate(self._buffer)
        s = np.clip(s, g_min, g_max)
        interior = s[(s > g_min) & (s < g_max)]
        k_interior = 2 ** bits - 2
        if k_interior <= 0:  # 1-bit codebook is just the two bounds
            return np.array([g_min, g_max])
        if interior.size < k_interior:
            cq = np.linspace(g_min, g_max, k_interior + 2)[1:-1]
        else:
            cq = kmeans_1d(interior, k_interior, iters=iters, seed=seed)
            if cq.size < k_interior:  # degenerate interior: pad evenly
                pad = np.linspace(g_min, g_max, k_interior - cq.size + 2)[1:-1]
                cq = np.sort(np.concatenate([cq, pad]))
        centers = np.concatenate([[g_min], cq, [g_max]])
        return np.sort(centers)


def fit_bs_kmq(samples: np.ndarray, bits: int, alpha: float = DEFAULT_ALPHA,
               batches: int = 8, iters: int = 50, seed: int = 0) -> np.ndarray:
    """One-shot convenience wrapper: split ``samples`` into calibration batches."""
    samples = np.asarray(samples, dtype=np.float64).ravel()
    if samples.size == 0:
        raise ValueError("cannot fit on empty sample set")
    calib = BSKMQCalibrator(alpha=alpha, seed=seed)
    for chunk in np.array_split(samples, max(1, min(batches, samples.size))):
        if chunk.size:
            calib.observe(chunk)
    return calib.finish(bits, iters=iters, seed=seed)
