//! Quickstart: calibrate a BS-KMQ codebook on one layer's activations and
//! compare its deployed quantization error against the four baselines —
//! the library's core loop in ~40 lines.  Runs on whichever execution
//! backend is selected (`BSKMQ_BACKEND=native|xla|auto`).
//!
//!   cargo run --release --example quickstart

use bskmq::backend::{load, Backend, BackendKind};
use bskmq::coordinator::calibrate::Calibrator;
use bskmq::data::dataset::ModelData;
use bskmq::quant::{Method, QuantSpec};

fn main() -> anyhow::Result<()> {
    let artifacts = bskmq::artifacts_dir();

    // load the mini-ResNet on the selected backend + its synthetic dataset
    let backend = load(BackendKind::from_env(), &artifacts, "resnet")?;
    let data = ModelData::load(&artifacts, "resnet")?;
    println!(
        "model: resnet ({} quantized layers, batch {}, {} backend)",
        backend.manifest().nq(),
        backend.manifest().batch,
        backend.name()
    );

    // stream calibration batches through the collect entry point
    let calib = Calibrator::with_uniform(backend.as_ref(), QuantSpec::new(Method::BsKmq, 3));
    let samples = calib.collect_samples(&data, 8)?;
    let layer0 = &samples[0];
    println!(
        "collected {} activations from layer '{}'",
        layer0.len(),
        backend.manifest().qlayers[0].name
    );

    // fit every quantizer at 3 bits and compare deployed MSE
    let bits = 3;
    println!("3-bit quantizer MSE (after §2.3 hardware projection):");
    let bs = Method::BsKmq.fit_hw(layer0, bits, 0).mse(layer0);
    for m in Method::ALL {
        let mse = m.fit_hw(layer0, bits, 0).mse(layer0);
        println!(
            "  {:<10} {:>10.6}  ({:.2}x vs BS-KMQ)",
            m.name(),
            mse,
            mse / bs
        );
    }

    // the BS-KMQ codebook, as the IM NL-ADC would be programmed
    let cb = Method::BsKmq.fit_hw(layer0, bits, 0);
    println!("BS-KMQ centers: {:?}", round3(&cb.centers));
    println!("floor-ADC refs: {:?}", round3(&cb.refs));
    Ok(())
}

fn round3(xs: &[f64]) -> Vec<f64> {
    xs.iter().map(|x| (x * 1000.0).round() / 1000.0).collect()
}
