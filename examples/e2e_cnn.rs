//! End-to-end driver (the repository's headline validation run): exercise
//! every layer of the stack on the mini-ResNet workload.
//!
//!   1. load the model artifacts on the selected execution backend (the
//!      PJRT engine over the AOT graphs with `--features xla`, the native
//!      integer IMC engine otherwise);
//!   2. stream calibration batches through `collect`, run Algorithm 1
//!      per layer in Rust, program the NL-ADC codebooks;
//!   3. evaluate PTQ accuracy through `qfwd`: float-reference vs linear
//!      vs BS-KMQ at 3 bits, then add linear 2-bit weights and the
//!      circuit-sim-derived TT conversion noise (the deployed operating
//!      point of Table 1: 6/2/3b);
//!   4. run the system-level accelerator simulation for the paper-scale
//!      ResNet-18 and print the Table-1 row.
//!
//!   cargo run --release --example e2e_cnn
//!
//! The output of this run is recorded in EXPERIMENTS.md §E2E.

use std::time::Instant;

use bskmq::arch::accelerator::{Accelerator, SystemConfig};
use bskmq::backend::{load, Backend, BackendKind};
use bskmq::circuit::montecarlo::{default_4bit_steps, MonteCarlo, MonteCarloConfig};
use bskmq::circuit::{Corner, MAC_UNITS_PER_CELL};
use bskmq::coordinator::calibrate::Calibrator;
use bskmq::coordinator::ptq::PtqEvaluator;
use bskmq::data::dataset::ModelData;
use bskmq::nn::zoo::resnet18_cifar;
use bskmq::quant::{Method, QuantSpec};

fn main() -> anyhow::Result<()> {
    let t0 = Instant::now();
    let artifacts = bskmq::artifacts_dir();
    let backend = load(BackendKind::from_env(), &artifacts, "resnet")?;
    println!("[1/4] loading artifacts ({} backend)", backend.name());
    let data = ModelData::load(&artifacts, "resnet")?;

    println!("[2/4] calibrating (Algorithm 1, 8 batches x 32)");
    let bits = 3;
    let be = backend.as_ref();
    let bs = Calibrator::with_uniform(be, QuantSpec::new(Method::BsKmq, bits))
        .calibrate(&data, 8)?;
    let lin = Calibrator::with_uniform(be, QuantSpec::new(Method::Linear, bits))
        .calibrate(&data, 8)?;
    // float reference: 7-bit linear codebooks ~ no activation quantization
    let float_ref = Calibrator::with_uniform(be, QuantSpec::new(Method::Linear, 7))
        .calibrate(&data, 8)?;
    for (i, q) in be.manifest().qlayers.iter().enumerate() {
        println!(
            "    layer {:<6} range [{:.3}, {:.3}] min-step {:.4}",
            q.name,
            bs.nl_books[i].centers.first().unwrap(),
            bs.nl_books[i].centers.last().unwrap(),
            bs.nl_books[i].min_step()
        );
    }

    println!("[3/4] PTQ evaluation (16 batches x 32 = 512 test samples)");
    let ev = PtqEvaluator::new(be);
    let n = 16;
    let acc_float = ev.evaluate(&data, &float_ref.programmed, 0.0, n, 1)?.accuracy;
    let acc_lin = ev.evaluate(&data, &lin.programmed, 0.0, n, 1)?.accuracy;
    let acc_bs = ev.evaluate(&data, &bs.programmed, 0.0, n, 1)?.accuracy;
    println!("    float-ref (7b)   acc {acc_float:.4}");
    println!("    linear    ({bits}b)  acc {acc_lin:.4}");
    println!("    BS-KMQ    ({bits}b)  acc {acc_bs:.4}   (gap vs linear {:+.1} pts)",
             (acc_bs - acc_lin) * 100.0);

    // deployed operating point: + weight quantization + TT conversion
    // noise.  Weights use 4 bits — the mini's iso-accuracy point of the
    // paper's 2-bit on ResNet-18 (EXPERIMENTS.md §Fig6 notes) — and the
    // NL-ADC codebooks are recalibrated on the quantized-weight hardware
    // (Algorithm 1 runs on the deployed macro).
    let mc = MonteCarlo::new(MonteCarloConfig::default());
    let tt = mc.run(Corner::TT, &default_4bit_steps(), 42);
    let sigma_lsb = (tt.sigma / MAC_UNITS_PER_CELL) as f32;
    let wq = ev.quantize_weights(4)?;
    let wq_books =
        Calibrator::with_uniform(wq.as_ref(), QuantSpec::new(Method::BsKmq, bits))
            .calibrate(&data, 8)?;
    let evw = PtqEvaluator::new(wq.as_ref());
    let acc_deploy = evw
        .evaluate(&data, &wq_books.programmed, sigma_lsb, n, 1)?
        .accuracy;
    println!(
        "    deployed (6/4/{bits}b + TT noise sigma {:.3} LSB) acc {:.4} (loss {:.2} pts vs float)",
        sigma_lsb,
        acc_deploy,
        (acc_float - acc_deploy) * 100.0
    );

    println!("[4/4] system-level simulation (paper-scale ResNet-18, 6/2/3b)");
    let sys = Accelerator::new(SystemConfig::paper_system());
    let r = sys.simulate(&resnet18_cifar());
    println!(
        "    {:.2} TOPS, {:.1} TOPS/W, {:.3} ms/inference, {:.1} uJ/inference",
        r.tops, r.tops_per_watt, r.latency_ms, r.total_energy_uj
    );
    println!("done in {:.1}s", t0.elapsed().as_secs_f64());
    Ok(())
}
