//! ADC explorer: the hardware-facing example.  Programs codebooks of
//! every resolution into the reconfigurable IM NL-ADC, sweeps process
//! corners with the behavioral circuit simulator (Fig. 7), and prints
//! the §2.3 bitcell/area accounting.
//!
//!   cargo run --release --example adc_explorer

use bskmq::adc::nl_adc::{max_resolution, nl_vs_linear_cells, NlAdc, NlAdcConfig};
use bskmq::circuit::montecarlo::{MonteCarlo, MonteCarloConfig};
use bskmq::circuit::Corner;
use bskmq::data::activations::ActivationProfile;
use bskmq::macro_model::MacroArea;
use bskmq::quant::Method;

fn main() -> anyhow::Result<()> {
    println!("reconfigurable IM NL-ADC: max resolution {} bits", max_resolution());

    // 1. program BS-KMQ codebooks at every resolution
    let xs = ActivationProfile::ReluConv.sample(40_000, 9);
    println!("\nbitcell accounting per resolution (NL vs linear ramp):");
    for bits in 1..=7u32 {
        let cb = Method::BsKmq.fit_hw(&xs, bits, 0);
        let cfg = NlAdcConfig::from_codebook(&cb, bits)?;
        let (nl, lin) = nl_vs_linear_cells(bits);
        println!(
            "  {bits}b: {:>3} cells used (budget {:>3} NL / {:>3} linear incl. calib)",
            cfg.cells_used(),
            nl,
            lin
        );
    }

    // 2. convert a sweep through the 4-bit ADC
    let cb = Method::BsKmq.fit_hw(&xs, 4, 0);
    let adc = NlAdc::new(NlAdcConfig::from_codebook(&cb, 4)?);
    println!("\n4-bit transfer function (input -> code -> center):");
    let lo = cb.centers[0];
    let hi = *cb.centers.last().unwrap();
    for i in 0..8 {
        let v = lo + (hi - lo) * i as f64 / 7.0;
        let code = adc.convert(v);
        println!("  {:>8.3} -> code {:>2} -> {:>8.3}", v, code, cb.centers[code]);
    }

    // 3. process-corner Monte-Carlo (Fig. 7)
    println!("\nconversion-error statistics per corner (MAC units, min step 10):");
    let steps = NlAdcConfig::from_codebook(&cb, 4)?.steps;
    let mc = MonteCarlo::new(MonteCarloConfig::default());
    for s in mc.run_corners(&steps, 7) {
        println!(
            "  {:<3} N({:+.2}, {:.2})  code-error rate {:.3}",
            s.corner.name(),
            s.mu,
            s.sigma,
            s.code_error_rate
        );
    }
    let off = MonteCarlo::new(MonteCarloConfig {
        replica_bias: false,
        ..Default::default()
    })
    .run(Corner::SS, &steps, 7);
    println!("  SS without replica biasing: sigma {:.2} (ablation)", off.sigma);

    // 4. area story (Fig. 8(b))
    let a = MacroArea::proposed();
    println!(
        "\narea: macro {:.3} mm^2, ADC overhead {:.1}% of MAC array (7x better than ramp-ADC [15])",
        a.total(),
        a.adc_overhead_ratio() * 100.0
    );
    Ok(())
}
