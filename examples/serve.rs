//! Serving example: start a two-model replica-pool registry in-process,
//! fire concurrent client threads at both models, and report latency /
//! throughput, the per-replica batching behaviour, the observability
//! surfaces (JSON stats, request-lifecycle spans, quantization-health
//! Prometheus series), admission control rejecting a burst against a
//! tiny queue, deadline shedding answering a burst with explicit
//! overload replies, and the TCP front serving pipelined NODELAY
//! clients over real sockets.  Falls back to synthetic artifacts when
//! the trained ones are absent, so it runs in any checkout:
//!
//!   cargo run --release --example serve
//!   BSKMQ_REPLICAS=4 cargo run --release --example serve

use std::io::{BufRead, BufReader, Write};
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Instant;

use bskmq::backend::BackendKind;
use bskmq::coordinator::front::{FrontKind, ServeFront};
use bskmq::coordinator::pool::{
    ModelPool, ModelRegistry, ObsConfig, PoolConfig,
};
use bskmq::data::dataset::ModelData;
use bskmq::obs::TraceSink;

fn main() -> anyhow::Result<()> {
    // trained artifacts when present, synthetic fallback otherwise
    let artifacts = bskmq::data::synth::ensure_artifacts()?;
    println!("artifacts: {}", artifacts.display());
    let replicas: usize = std::env::var("BSKMQ_REPLICAS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(2);
    // observability: sample every 8th request span into a memory sink,
    // profile every 4th batch for the per-op breakdown
    let sink = TraceSink::memory();
    let mut cfg = PoolConfig {
        backend: BackendKind::from_env(),
        replicas,
        queue_depth: 512,
        obs: ObsConfig {
            trace_sample_every: 8,
            trace_sink: Some(sink.clone()),
            profile_every: 4,
            ..ObsConfig::default()
        },
        ..PoolConfig::default()
    };
    let models: Vec<String> =
        vec!["resnet".to_string(), "vgg".to_string()];
    println!(
        "starting registry: {} x {replicas} replica(s), manifest quant specs, {} backend",
        models.join("+"),
        cfg.backend.name()
    );
    let registry = match ModelRegistry::start(&artifacts, &models, &cfg) {
        Ok(r) => r,
        Err(e) if cfg.replicas > 1 => {
            // e.g. the XLA engine cannot replicate; demo with one worker
            eprintln!("{} replicas unavailable ({e:#}); using 1", cfg.replicas);
            cfg.replicas = 1;
            ModelRegistry::start(&artifacts, &models, &cfg)?
        }
        Err(e) => return Err(e),
    };

    // real test inputs as the request stream, both models concurrently
    let n_clients_per_model = 4usize;
    let reqs_per_client = 32usize;
    let n_requests = models.len() * n_clients_per_model * reqs_per_client;
    println!(
        "firing {n_requests} requests from {} client threads",
        models.len() * n_clients_per_model
    );
    let latency_us = AtomicU64::new(0);
    let t0 = Instant::now();
    std::thread::scope(|s| -> anyhow::Result<()> {
        for model in &models {
            let data = ModelData::load(&artifacts, model)?;
            let in_elems: usize = data.x_test.shape[1..].iter().product();
            let pool = registry
                .get(model)
                .expect("registry serves what it started");
            // one shared copy of the test split per model
            let x_test = std::sync::Arc::new(data.x_test);
            for c in 0..n_clients_per_model {
                let client = pool.client();
                let lat = &latency_us;
                let x_test = x_test.clone();
                s.spawn(move || {
                    for r in 0..reqs_per_client {
                        let idx = (c * 97 + r * 13) % x_test.shape[0];
                        let x = x_test.data
                            [idx * in_elems..(idx + 1) * in_elems]
                            .to_vec();
                        let t = Instant::now();
                        let logits = client.infer(x).expect("serve failed");
                        lat.fetch_add(
                            t.elapsed().as_micros() as u64,
                            Ordering::Relaxed,
                        );
                        assert_eq!(logits.len(), client.num_classes());
                    }
                });
            }
        }
        Ok(())
    })?;
    let wall = t0.elapsed();
    let mean_lat_ms =
        latency_us.load(Ordering::Relaxed) as f64 / n_requests as f64 / 1e3;
    println!(
        "served {n_requests} requests in {:.2}s -> {:.1} req/s, mean latency {:.1} ms",
        wall.as_secs_f64(),
        n_requests as f64 / wall.as_secs_f64(),
        mean_lat_ms
    );
    println!("{}", registry.summary());

    // the `stats` protocol command serves exactly this JSON
    println!("\nstats (JSON): {}", registry.stats_json());
    for pool in registry.pools() {
        let tr = pool.tracer();
        println!(
            "{}: spans opened={} closed={} emitted={} (sampled 1/8)",
            pool.model,
            tr.opened(),
            tr.closed(),
            tr.emitted()
        );
    }
    if let Some(line) = sink.lines().first() {
        println!("sample span: {line}");
    }
    // quantization-health series from the `metrics` Prometheus page
    let page = registry.prometheus();
    println!("\nquant-health series (from `metrics`):");
    for line in page
        .lines()
        .filter(|l| l.starts_with("bskmq_saturation_rate"))
        .take(6)
    {
        println!("  {line}");
    }

    // admission control: a depth-2 queue under a 64-burst rejects loudly
    println!("\nadmission-control demo (queue depth 2, replicas 1):");
    let tiny = ModelPool::start(
        artifacts.clone(),
        "resnet".to_string(),
        &PoolConfig {
            backend: cfg.backend,
            replicas: 1,
            queue_depth: 2,
            calib_batches: 2,
            ..PoolConfig::default()
        },
    )?;
    let client = tiny.client();
    let data = ModelData::load(&artifacts, "resnet")?;
    let in_elems: usize = data.x_test.shape[1..].iter().product();
    let mut kept = Vec::new();
    for _ in 0..64 {
        if let Ok(rx) = client.submit(data.x_test.data[..in_elems].to_vec()) {
            kept.push(rx);
        }
    }
    for rx in &kept {
        let _ = rx.recv();
    }
    println!(
        "  burst of 64: {} accepted (all answered), {} rejected",
        kept.len(),
        tiny.rejected()
    );
    drop(tiny);

    // deadline shedding: with a zero deadline every admitted request is
    // past-due at batch assembly, so the pool answers the whole burst
    // with explicit overload replies instead of hanging clients
    println!("\ndeadline-shedding demo (deadline 0 ms, replicas 1):");
    let shedder = ModelPool::start(
        artifacts.clone(),
        "resnet".to_string(),
        &PoolConfig {
            backend: cfg.backend,
            replicas: 1,
            queue_depth: 256,
            calib_batches: 2,
            request_deadline: std::time::Duration::ZERO,
            ..PoolConfig::default()
        },
    )?;
    let client = shedder.client();
    let rxs: Vec<_> = (0..32)
        .filter_map(|_| {
            client.submit(data.x_test.data[..in_elems].to_vec()).ok()
        })
        .collect();
    let mut overloads = 0usize;
    for rx in rxs {
        if let Ok(Err(e)) = rx.recv() {
            if e.is_overload() {
                overloads += 1;
            }
        }
    }
    println!(
        "  burst of 32: {overloads} shed with explicit overload replies \
         (pool shed counter {})",
        shedder.shed()
    );
    drop(shedder);

    // the TCP front: epoll event loop on linux, thread-per-connection
    // elsewhere.  Protocol clients always set TCP_NODELAY — the
    // line-oriented protocol writes one small reply per request, which
    // Nagle would otherwise hold back.
    let kind = FrontKind::default_for_platform();
    println!("\nTCP front demo ({} front):", kind.name());
    let registry = std::sync::Arc::new(registry);
    let listener = std::net::TcpListener::bind("127.0.0.1:0")?;
    let mut front = ServeFront::spawn(registry.clone(), listener, kind)?;
    let stream = std::net::TcpStream::connect(front.addr())?;
    stream.set_nodelay(true)?;
    let mut out = stream.try_clone()?;
    let mut reader = BufReader::new(stream);
    let floats: Vec<String> = data.x_test.data[..in_elems]
        .iter()
        .map(|v| v.to_string())
        .collect();
    let infer_line = floats.join(",");
    // pipelined: three inferences and a stats line in one write
    let mut payload = String::new();
    for _ in 0..3 {
        payload.push_str(&infer_line);
        payload.push('\n');
    }
    payload.push_str("stats --text\n");
    out.write_all(payload.as_bytes())?;
    let mut reply = String::new();
    for i in 0..4 {
        reply.clear();
        reader.read_line(&mut reply)?;
        let trimmed = reply.trim_end();
        let shown = if trimmed.len() > 72 {
            &trimmed[..72]
        } else {
            trimmed
        };
        println!("  reply {i}: {shown}");
    }
    front.stop();
    Ok(())
}
