//! Serving example: start the batched inference server in-process, fire
//! concurrent client threads at it, and report latency / throughput and
//! the dynamic batcher's behaviour (full batches vs singles).
//!
//!   cargo run --release --example serve

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Instant;

use bskmq::backend::BackendKind;
use bskmq::coordinator::server::InferenceServer;
use bskmq::data::dataset::ModelData;
use bskmq::quant::Method;

fn main() -> anyhow::Result<()> {
    let artifacts = bskmq::artifacts_dir();
    let model = "resnet";
    let kind = BackendKind::from_env();
    println!(
        "starting inference server ({model}, 3-bit BS-KMQ, {} backend)...",
        kind.name()
    );
    let server = InferenceServer::start(
        artifacts.clone(),
        model.into(),
        kind,
        Method::BsKmq,
        3,
        0.0,
        8,
    )?;

    // real test inputs as the request stream
    let data = ModelData::load(&artifacts, model)?;
    let in_elems: usize = data.x_test.shape[1..].iter().product();
    let n_requests = 256usize;
    let n_clients = 8usize;

    println!("firing {n_requests} requests from {n_clients} client threads");
    let latency_us = Arc::new(AtomicU64::new(0));
    let t0 = Instant::now();
    std::thread::scope(|s| {
        for c in 0..n_clients {
            let tx = server.client();
            let lat = latency_us.clone();
            let x_test = &data.x_test;
            s.spawn(move || {
                for r in 0..n_requests / n_clients {
                    let idx = (c * 97 + r * 13) % (x_test.shape[0]);
                    let x =
                        x_test.data[idx * in_elems..(idx + 1) * in_elems].to_vec();
                    let (reply_tx, reply_rx) = std::sync::mpsc::channel();
                    let t = Instant::now();
                    tx.send(bskmq::coordinator::server::Request {
                        x,
                        reply: reply_tx,
                    })
                    .unwrap();
                    let logits = reply_rx.recv().unwrap();
                    lat.fetch_add(t.elapsed().as_micros() as u64, Ordering::Relaxed);
                    assert_eq!(logits.len(), 10);
                }
            });
        }
    });
    let wall = t0.elapsed();
    let mean_lat_ms =
        latency_us.load(Ordering::Relaxed) as f64 / n_requests as f64 / 1e3;
    println!(
        "served {n_requests} requests in {:.2}s -> {:.1} req/s, mean latency {:.1} ms",
        wall.as_secs_f64(),
        n_requests as f64 / wall.as_secs_f64(),
        mean_lat_ms
    );
    println!("batcher: {}", server.stats.summary());
    Ok(())
}
