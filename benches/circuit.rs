//! Bench: circuit Monte-Carlo (Fig. 7 companion) — conversion throughput
//! of the behavioral simulator and the per-corner statistics table.
//!
//!   cargo bench --bench circuit

use bskmq::circuit::montecarlo::{default_4bit_steps, MonteCarlo, MonteCarloConfig};
use bskmq::circuit::Corner;
use bskmq::util::bench::{bench, black_box};

fn main() {
    let steps = default_4bit_steps();

    println!("=== Monte-Carlo conversion throughput ===");
    let mc = MonteCarlo::new(MonteCarloConfig {
        instances: 8,
        conversions: 256,
        ..Default::default()
    });
    let r = bench("8 instances x 256 conversions @TT", || {
        black_box(mc.run(Corner::TT, &steps, 1));
    });
    r.print_throughput(8.0 * 256.0, "conversions");

    println!("\n=== Fig.7 statistics (full run, 64 x 512) ===");
    let full = MonteCarlo::new(MonteCarloConfig::default());
    for s in full.run_corners(&steps, 42) {
        println!(
            "  {:<3} N({:+.2}, {:.2})  code-err {:.3}  ({} samples)",
            s.corner.name(),
            s.mu,
            s.sigma,
            s.code_error_rate,
            s.samples
        );
    }

    println!("\n=== replica-bias ablation across corners ===");
    let ab = MonteCarlo::new(MonteCarloConfig {
        replica_bias: false,
        ..Default::default()
    });
    for s in ab.run_corners(&steps, 42) {
        println!(
            "  {:<3} sigma {:.2} (bias off)",
            s.corner.name(),
            s.sigma
        );
    }
}
