//! Bench: system-level simulation (Table 1 + Fig. 8 companion) — the
//! accelerator model across all four paper-scale networks, ADC-bit and
//! weight-bit ablations, and simulator throughput.
//!
//!   cargo bench --bench system

use bskmq::arch::accelerator::{Accelerator, SystemConfig};
use bskmq::arch::baselines::baseline_designs;
use bskmq::macro_model::{MacroConfig, MacroEnergy};
use bskmq::nn::zoo::{distilbert, inception_v3, resnet18_cifar, vgg16_cifar};
use bskmq::util::bench::{bench, black_box};

fn main() {
    println!("=== Table 1 regeneration ===");
    let acc = Accelerator::new(SystemConfig::paper_system());
    let nets = [
        resnet18_cifar(),
        vgg16_cifar(),
        inception_v3(),
        distilbert(),
    ];
    for net in &nets {
        let r = acc.simulate(net);
        println!(
            "  {:<12} {:>7.2} TOPS  {:>7.1} TOPS/W  {:>8.2} ms  {:>8.1} uJ",
            r.network, r.tops, r.tops_per_watt, r.latency_ms, r.total_energy_uj
        );
    }
    let ours = acc.simulate(&resnet18_cifar());
    for d in baseline_designs() {
        if let Some(t) = d.tops {
            println!(
                "  vs {:<12} speedup {:>5.2}x  energy-eff {:>5.1}x",
                d.label,
                ours.tops / t,
                ours.tops_per_watt / d.tops_per_watt.1
            );
        }
    }

    println!("\n=== ablation: ADC resolution (ResNet-18, 6/2b) ===");
    for out_bits in 2..=6u32 {
        let cfg = SystemConfig {
            macro_cfg: MacroConfig {
                out_bits,
                ..MacroConfig::paper_system()
            },
            ..SystemConfig::paper_system()
        };
        let r = Accelerator::new(cfg).simulate(&resnet18_cifar());
        println!(
            "  {out_bits}b ADC: {:>6.2} TOPS  {:>7.1} TOPS/W",
            r.tops, r.tops_per_watt
        );
    }

    println!("\n=== ablation: weight precision ===");
    for w_bits in 2..=4u32 {
        let cfg = MacroConfig {
            w_bits,
            ..MacroConfig::paper_system()
        };
        println!(
            "  {w_bits}b weights: macro {:>6.1} TOPS/W, {:>5.3} TOPS",
            MacroEnergy::tops_per_watt(cfg),
            MacroEnergy::tops(cfg)
        );
    }

    println!("\n=== simulator throughput ===");
    let net = resnet18_cifar();
    let r = bench("simulate resnet18 end-to-end", || {
        black_box(acc.simulate(&net));
    });
    r.print();
}
