//! Bench: quantizer fitting + deployed-MSE regeneration (Fig. 1 / Fig. 4
//! companion).  Times the calibration hot path (k-means dominates) and
//! prints the MSE tables on controlled activation profiles.
//!
//!   cargo bench --bench quantizers

use bskmq::data::activations::ActivationProfile;
use bskmq::quant::Method;
use bskmq::util::bench::{bench, black_box};

fn main() {
    println!("=== quantizer fitting throughput (50k samples) ===");
    let xs = ActivationProfile::ReluConv.sample(50_000, 3);
    for m in Method::ALL {
        let r = bench(&format!("fit {} @3b", m.name()), || {
            black_box(m.fit(&xs, 3, 0));
        });
        r.print();
    }
    let cb = Method::BsKmq.fit_hw(&xs, 3, 0);
    let r = bench("quantize 50k through codebook", || {
        black_box(cb.mse(&xs));
    });
    r.print_throughput(xs.len() as f64, "samples");

    println!("\n=== deployed MSE, controlled profiles (paper Fig.1/Fig.4 shape) ===");
    for profile in [
        ActivationProfile::ReluConv,
        ActivationProfile::ReluClamped,
        ActivationProfile::AttentionSigned,
    ] {
        for bits in [3u32, 4] {
            let xs = profile.sample(60_000, 11);
            let bs = Method::BsKmq.fit_hw(&xs, bits, 0).mse(&xs);
            print!("{:<17} {bits}b  ", profile.name());
            for m in Method::ALL {
                let mse = m.fit_hw(&xs, bits, 0).mse(&xs);
                print!("{}={:.4} ({:.1}x)  ", m.name(), mse, mse / bs);
            }
            println!();
        }
    }
}
