//! Bench: sharded vs serial calibration throughput.
//!
//! Streams the same calibration batches through 1/2/4/8 shards (each
//! shard a `Backend::replicate` clone on its own scoped thread) and
//! reports wall-clock per calibration — asserting along the way that
//! every shard count reproduces the serial codebooks bit for bit, which
//! is the whole point of the mergeable estimator design.
//!
//!   cargo bench --bench calibration

use std::time::Instant;

use bskmq::backend::{load, Backend, BackendKind};
use bskmq::coordinator::calibrate::Calibrator;
use bskmq::data::dataset::ModelData;

fn main() -> anyhow::Result<()> {
    let artifacts = bskmq::data::synth::ensure_artifacts()?;
    println!("artifacts: {}", artifacts.display());
    for model in ["resnet", "vgg"] {
        let be = load(BackendKind::Native, &artifacts, model)?;
        let data = ModelData::load(&artifacts, model)?;
        let calib = Calibrator::from_manifest(be.as_ref());
        let n_batches = 8;
        let iters = 5;
        println!(
            "=== {model}: {n_batches} batches, {} q-layers, spec {} ===",
            be.manifest().nq(),
            calib.specs()[0].summary()
        );
        let mut reference: Option<Vec<u64>> = None;
        for shards in [1usize, 2, 4, 8] {
            let t0 = Instant::now();
            let mut last = None;
            for _ in 0..iters {
                last = Some(calib.calibrate_sharded(&data, n_batches, shards)?);
            }
            let dt_ms = t0.elapsed().as_secs_f64() / iters as f64 * 1e3;
            let r = last.unwrap();
            let sig: Vec<u64> = r
                .nl_books
                .iter()
                .chain(r.tile_books.iter())
                .flat_map(|b| b.centers.iter().map(|c| c.to_bits()))
                .collect();
            match &reference {
                None => reference = Some(sig),
                Some(want) => assert_eq!(
                    want, &sig,
                    "{shards}-shard codebooks diverged from serial"
                ),
            }
            println!("  shards {shards}: {dt_ms:8.2} ms/calibration");
        }
    }
    println!("codebooks bit-identical across all shard counts");
    Ok(())
}
