//! Bench: NativeBackend vs XlaBackend forward latency on the resnet-mini
//! config — single-sample and batch-32 qfwd, plus the collect path.
//! The xla column needs `--features xla` and the lowered HLO artifacts;
//! the native column only needs the manifest + weights container.
//!
//!   cargo bench --bench backends
//!
//! Requires `make artifacts`.

use bskmq::backend::{load, Backend, BackendKind};
use bskmq::coordinator::calibrate::Calibrator;
use bskmq::data::dataset::ModelData;
use bskmq::quant::Method;
use bskmq::util::bench::{bench, black_box};

fn main() -> anyhow::Result<()> {
    let artifacts = bskmq::artifacts_dir();
    if !artifacts.join("resnet_manifest.json").exists() {
        eprintln!("SKIP: run `make artifacts` first");
        return Ok(());
    }

    let mut backends: Vec<Box<dyn Backend>> =
        vec![load(BackendKind::Native, &artifacts, "resnet")?];
    if cfg!(feature = "xla") {
        match load(BackendKind::Xla, &artifacts, "resnet") {
            Ok(b) => backends.push(b),
            Err(e) => eprintln!("xla column skipped: {e:#}"),
        }
    } else {
        eprintln!("xla column skipped (build with --features xla)");
    }

    let data = ModelData::load(&artifacts, "resnet")?;
    for be in &backends {
        let name = be.name();
        println!("=== {name} backend (resnet) ===");
        let calib =
            Calibrator::new(be.as_ref(), Method::BsKmq, 3).calibrate(&data, 8)?;
        let batch = be.manifest().batch;
        let in_elems = be.manifest().input_elems();
        let xb = &data.x_test.data[..batch * in_elems];
        let x1 = &data.x_test.data[..in_elems];

        let r = bench(&format!("{name}: qfwd batch-{batch}"), || {
            black_box(be.run_qfwd(xb, &calib.programmed, 0.0, 7).unwrap());
        });
        r.print_throughput(batch as f64, "inferences");

        if be.supports_batch(1) {
            let r = bench(&format!("{name}: qfwd batch-1"), || {
                black_box(be.run_qfwd(x1, &calib.programmed, 0.0, 7).unwrap());
            });
            r.print_throughput(1.0, "inferences");
        } else {
            println!("{name}: no batch-1 path");
        }

        let r = bench(&format!("{name}: collect batch-{batch}"), || {
            black_box(be.run_collect(xb).unwrap());
        });
        r.print_throughput(batch as f64, "samples");
        println!();
    }
    Ok(())
}
