//! Bench: NativeBackend vs XlaBackend forward latency on the resnet-mini
//! config — single-sample and batch-32 qfwd, plus the collect path, a
//! per-op timing breakdown from the scratch-arena graph executor, and
//! (native only) forced-scalar and forced-spawn phases isolating the
//! SIMD and executor-pool wins respectively.
//! The xla column needs `--features xla` and the lowered HLO artifacts;
//! the native column only needs the manifest + weights container.
//!
//!   cargo bench --bench backends
//!
//! Uses `make artifacts` outputs when present, the synthetic set
//! otherwise.
//!
//! The per-op rows printed here are the same `run_qfwd_profiled`
//! breakdown the serving path samples behind `ObsConfig::profile_every`
//! (spans carry them) and `bskmq bench` persists into BENCH_*.json —
//! one instrumentation source, three consumers.
//!
//! Baseline note: the graph executor replaced the hardcoded per-model
//! forwards of commit 695adc0 ("PR 2").  Both paths run the identical
//! kernel sequence (the golden suite pins logits bit-identical), so any
//! executor overhead is pure dispatch + arena bookkeeping; to measure it
//! directly, run this bench, then `git checkout 695adc0 && cargo bench
//! --bench backends` and compare the qfwd rows.

use std::collections::BTreeMap;

use bskmq::backend::native::{exec_pool, simd, NativeBackend};
use bskmq::backend::{load, Backend, BackendKind};
use bskmq::coordinator::calibrate::Calibrator;
use bskmq::data::dataset::ModelData;
use bskmq::quant::{Method, QuantSpec};
use bskmq::util::bench::{bench, black_box};

fn main() -> anyhow::Result<()> {
    let artifacts = bskmq::data::synth::ensure_artifacts()?;

    let mut backends: Vec<Box<dyn Backend>> =
        vec![load(BackendKind::Native, &artifacts, "resnet")?];
    if cfg!(feature = "xla") {
        match load(BackendKind::Xla, &artifacts, "resnet") {
            Ok(b) => backends.push(b),
            Err(e) => eprintln!("xla column skipped: {e:#}"),
        }
    } else {
        eprintln!("xla column skipped (build with --features xla)");
    }

    let data = ModelData::load(&artifacts, "resnet")?;
    for be in &backends {
        let name = be.name();
        println!("=== {name} backend (resnet) ===");
        let calib =
            Calibrator::with_uniform(be.as_ref(), QuantSpec::new(Method::BsKmq, 3))
                .calibrate(&data, 8)?;
        let batch = be.manifest().batch;
        let in_elems = be.manifest().input_elems();
        let xb = &data.x_test.data[..batch * in_elems];
        let x1 = &data.x_test.data[..in_elems];

        let r = bench(&format!("{name}: qfwd batch-{batch}"), || {
            black_box(be.run_qfwd(xb, &calib.programmed, 0.0, 7).unwrap());
        });
        r.print_throughput(batch as f64, "inferences");

        if name == "native" {
            simd::force_scalar(true);
            let rs = bench(&format!("{name}: qfwd batch-{batch} (scalar)"), || {
                black_box(be.run_qfwd(xb, &calib.programmed, 0.0, 7).unwrap());
            });
            simd::force_scalar(false);
            rs.print_throughput(batch as f64, "inferences");
            println!(
                "{name}: qfwd vectorized speedup vs forced scalar: {:.2}x",
                rs.mean_ns() as f64 / r.mean_ns().max(1) as f64
            );

            // same forward with the persistent executor pool disabled:
            // every par_row_blocks call pays a fresh std::thread::scope
            // spawn per op (the pre-pool dispatch path).  The default
            // `r` timing above already ran through the pool with the
            // cached LayerPlan, so rp/r is the pool+plan win.
            exec_pool::force_spawn(true);
            let rp = bench(&format!("{name}: qfwd batch-{batch} (spawn)"), || {
                black_box(be.run_qfwd(xb, &calib.programmed, 0.0, 7).unwrap());
            });
            exec_pool::force_spawn(false);
            rp.print_throughput(batch as f64, "inferences");
            println!(
                "{name}: qfwd executor-pool speedup vs per-op spawn: {:.2}x",
                rp.mean_ns() as f64 / r.mean_ns().max(1) as f64
            );
        }

        if be.supports_batch(1) {
            let r = bench(&format!("{name}: qfwd batch-1"), || {
                black_box(be.run_qfwd(x1, &calib.programmed, 0.0, 7).unwrap());
            });
            r.print_throughput(1.0, "inferences");
        } else {
            println!("{name}: no batch-1 path");
        }

        let r = bench(&format!("{name}: collect batch-{batch}"), || {
            black_box(be.run_collect(xb).unwrap());
        });
        r.print_throughput(batch as f64, "samples");
        println!();
    }

    // --- per-op breakdown (native graph executor, every topology) ---
    // timings come from the scratch-arena interpreter itself, so the
    // split reflects exactly what the serving hot path executes.  Each
    // model is profiled twice — `simd::force_scalar(true)` baseline,
    // then the runtime-dispatched vectorized path — and the delta column
    // is the measured per-op win of the SIMD kernels (DESIGN.md §12).
    const PROFILE_ITERS: usize = 20;
    for model in bskmq::data::synth::MODELS {
        // trained artifact dirs carry only the aot.py models (no mixer)
        let be = match NativeBackend::load(&artifacts, model) {
            Ok(be) => be,
            Err(e) => {
                eprintln!("per-op breakdown: {model} skipped ({e:#})");
                continue;
            }
        };
        let data = ModelData::load(&artifacts, model)?;
        let calib =
            Calibrator::with_uniform(&be, QuantSpec::new(Method::BsKmq, 3)).calibrate(&data, 8)?;
        let batch = be.manifest().batch;
        let xb = &data.x_test.data[..batch * be.manifest().input_elems()];

        // (label, sum nanos, out elems) per op, in graph order
        let profile = |force_scalar: bool| -> anyhow::Result<(
            BTreeMap<usize, (String, u128, usize)>,
            u128,
        )> {
            simd::force_scalar(force_scalar);
            let mut agg: BTreeMap<usize, (String, u128, usize)> =
                BTreeMap::new();
            let mut total: u128 = 0;
            for _ in 0..PROFILE_ITERS {
                let (_, timings) =
                    be.run_qfwd_profiled(xb, &calib.programmed, 0.0, 7)?;
                for (i, t) in timings.iter().enumerate() {
                    let e = agg.entry(i).or_insert_with(|| {
                        (format!("{} ({})", t.name, t.kind), 0, t.out_elems)
                    });
                    e.1 += t.nanos;
                    total += t.nanos;
                }
            }
            Ok((agg, total))
        };
        let (scalar_agg, scalar_total) = profile(true)?;
        let (agg, total) = profile(false)?;
        simd::force_scalar(false);

        println!(
            "=== per-op breakdown: {model} qfwd batch-{batch} \
             (mean over {PROFILE_ITERS} runs, vs forced-scalar) ==="
        );
        for (i, (label, nanos, out_elems)) in &agg {
            let mean_us = *nanos as f64 / PROFILE_ITERS as f64 / 1e3;
            let scalar_us = scalar_agg
                .get(i)
                .map(|e| e.1 as f64 / PROFILE_ITERS as f64 / 1e3)
                .unwrap_or(mean_us);
            let delta_ns = (scalar_us - mean_us) * 1e3;
            println!(
                "  {label:<24} {mean_us:>9.1} us  {:>5.1}%  \
                 scalar {scalar_us:>9.1} us  d {delta_ns:>+11.0} ns  \
                 out {out_elems}",
                100.0 * *nanos as f64 / total.max(1) as f64
            );
        }
        println!(
            "  {model} qfwd vectorized speedup vs scalar: {:.2}x",
            scalar_total as f64 / total.max(1) as f64
        );
        println!();
    }
    Ok(())
}
