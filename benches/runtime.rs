//! Bench: request-path latency of the selected backend — the L3 hot path
//! (qfwd execution, batch-32 and batch-1, the calibration path, and — on
//! xla builds — the standalone crossbar MAC kernel graph).
//!
//!   cargo bench --bench runtime
//!
//! Requires `make artifacts`.

use bskmq::backend::{load, Backend, BackendKind};
use bskmq::coordinator::calibrate::Calibrator;
use bskmq::data::dataset::ModelData;
use bskmq::quant::{Method, QuantSpec};
use bskmq::util::bench::{bench, black_box};

fn main() -> anyhow::Result<()> {
    let artifacts = bskmq::artifacts_dir();
    let backend = load(BackendKind::from_env(), &artifacts, "resnet")?;

    println!("=== qfwd request path (resnet, {} backend) ===", backend.name());
    let data = ModelData::load(&artifacts, "resnet")?;
    let calib =
        Calibrator::with_uniform(backend.as_ref(), QuantSpec::new(Method::BsKmq, 3))
            .calibrate(&data, 8)?;
    let batch = backend.manifest().batch;
    let in_elems = backend.manifest().input_elems();
    let xb = &data.x_test.data[..batch * in_elems];

    let r = bench("qfwd batch-32", || {
        black_box(backend.run_qfwd(xb, &calib.programmed, 0.0, 7).unwrap());
    });
    r.print_throughput(batch as f64, "inferences");
    if backend.supports_batch(1) {
        let x1 = &data.x_test.data[..in_elems];
        let r = bench("qfwd batch-1", || {
            black_box(
                backend.run_qfwd(x1, &calib.programmed, 0.0, 7).unwrap(),
            );
        });
        r.print_throughput(1.0, "inferences");
    }
    let r = bench("collect batch-32 (calibration path)", || {
        black_box(backend.run_collect(xb).unwrap());
    });
    r.print_throughput(batch as f64, "samples");

    #[cfg(feature = "xla")]
    mac_tile_bench(&artifacts)?;
    Ok(())
}

/// Standalone crossbar MAC+ADC kernel graph (xla builds only).
#[cfg(feature = "xla")]
fn mac_tile_bench(artifacts: &std::path::Path) -> anyhow::Result<()> {
    use bskmq::quant::codebook::{Codebook, MAX_LEVELS};
    use bskmq::runtime::engine::{literal_f32, Engine};
    use bskmq::tensor::Tensor;

    println!("\n=== standalone crossbar MAC+ADC kernel graph ===");
    let engine = Engine::cpu()?;
    let exe = engine.load(artifacts.join("mac_tile.hlo.txt"))?;
    let (m, k, n) = (64usize, 512usize, 128usize);
    let x = Tensor::new(vec![m, k], vec![0.5; m * k])?;
    let w = Tensor::new(vec![k, n], vec![0.01; k * n])?;
    let cb = Codebook::linear(-50.0, 50.0, 7);
    let (refs, centers) = cb.padded(MAX_LEVELS);
    let args = vec![
        literal_f32(&x)?,
        literal_f32(&w)?,
        literal_f32(&Tensor::new(vec![MAX_LEVELS], refs)?)?,
        literal_f32(&Tensor::new(vec![MAX_LEVELS], centers)?)?,
    ];
    let r = bench("mac_tile 64x512x128 (2 crossbar tiles)", || {
        black_box(exe.run(&args).unwrap());
    });
    let macs = (m * k * n) as f64;
    r.print_throughput(macs * 2.0, "ops");
    Ok(())
}
