//! Bench: PJRT request-path latency — the L3 hot path (qfwd execution,
//! batch-32 and batch-1, and the standalone crossbar MAC kernel graph).
//!
//!   cargo bench --bench runtime
//!
//! Requires `make artifacts`.

use bskmq::coordinator::calibrate::Calibrator;
use bskmq::data::dataset::ModelData;
use bskmq::quant::codebook::{Codebook, MAX_LEVELS};
use bskmq::quant::Method;
use bskmq::runtime::engine::{literal_f32, Engine};
use bskmq::runtime::model::ModelRuntime;
use bskmq::tensor::Tensor;
use bskmq::util::bench::{bench, black_box};

fn main() -> anyhow::Result<()> {
    let artifacts = bskmq::artifacts_dir();
    let engine = Engine::cpu()?;

    println!("=== qfwd request path (resnet) ===");
    let runtime = ModelRuntime::load(&engine, &artifacts, "resnet")?;
    let data = ModelData::load(&artifacts, "resnet")?;
    let calib = Calibrator::new(&runtime, Method::BsKmq, 3).calibrate(&data, 8)?;
    let batch = runtime.manifest.batch;
    let in_elems = runtime.manifest.input_elems();
    let xb = &data.x_test.data[..batch * in_elems];

    let r = bench("qfwd batch-32", || {
        black_box(runtime.run_qfwd(xb, &calib.programmed, 0.0, 7).unwrap());
    });
    r.print_throughput(batch as f64, "inferences");
    if runtime.has_b1() {
        let x1 = &data.x_test.data[..in_elems];
        let r = bench("qfwd batch-1", || {
            black_box(
                runtime
                    .run_qfwd_b1(x1, &calib.programmed, 0.0, 7)
                    .unwrap(),
            );
        });
        r.print_throughput(1.0, "inferences");
    }
    let r = bench("collect batch-32 (calibration path)", || {
        black_box(runtime.run_collect(xb).unwrap());
    });
    r.print_throughput(batch as f64, "samples");

    println!("\n=== standalone crossbar MAC+ADC kernel graph ===");
    let exe = engine.load(artifacts.join("mac_tile.hlo.txt"))?;
    let (m, k, n) = (64usize, 512usize, 128usize);
    let x = Tensor::new(vec![m, k], vec![0.5; m * k])?;
    let w = Tensor::new(vec![k, n], vec![0.01; k * n])?;
    let cb = Codebook::linear(-50.0, 50.0, 7);
    let (refs, centers) = cb.padded(MAX_LEVELS);
    let args = vec![
        literal_f32(&x)?,
        literal_f32(&w)?,
        literal_f32(&Tensor::new(vec![MAX_LEVELS], refs)?)?,
        literal_f32(&Tensor::new(vec![MAX_LEVELS], centers)?)?,
    ];
    let r = bench("mac_tile 64x512x128 (2 crossbar tiles)", || {
        black_box(exe.run(&args).unwrap());
    });
    let macs = (m * k * n) as f64;
    r.print_throughput(macs * 2.0, "ops");
    Ok(())
}
