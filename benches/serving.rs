//! The closed-loop serving load harness (DESIGN.md §13): drive ≥1M
//! requests through the replica pools at controlled offered
//! concurrency and measure throughput, tail latency, and shed rate vs
//! offered load — the saturation numbers behind the ROADMAP's
//! millions-of-users claim.  Four phases:
//!
//! 1. **ladder** — offered load 1→256 closed-loop clients against a
//!    fixed 4-replica pool: throughput-vs-offered-load and p50/p99/p999.
//! 2. **overload** — 256 clients vs one replica with a tight deadline
//!    (well past 2× saturation): graceful degradation means admitted
//!    requests stay fast and the excess is shed with explicit overload
//!    replies, not a collapsing tail.
//! 3. **autoscale** — a 1..4-replica autoscaling pool under load:
//!    queue depth drives `Backend::replicate()` scale-up.
//! 4. **tcp** — the epoll event front end-to-end: pipelined NODELAY
//!    connections over real sockets.
//!
//!   cargo bench --bench serving
//!   BSKMQ_LOAD_TOTAL=50000  scale the request budget (default 1M)
//!   BSKMQ_LOAD_ASSERT=1     enforce p999/shed/accounting bounds (CI)
//!   BSKMQ_BENCH_OUT=DIR     also write BENCH_<rev>.json (schema v3)
//!   BSKMQ_THREADS=N         compute threads per replica

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::Arc;
use std::time::Duration;

use anyhow::{ensure, Result};

use bskmq::backend::BackendKind;
use bskmq::coordinator::front::{FrontKind, ServeFront};
use bskmq::coordinator::loadgen::closed_loop;
use bskmq::coordinator::pool::{ModelPool, ModelRegistry, PoolConfig};
use bskmq::data::dataset::ModelData;
use bskmq::data::synth;
use bskmq::obs::bench_report::{short_rev, BenchReport, ServingPoint};

const MODEL: &str = "resnet";

fn env_u64(key: &str, default: u64) -> u64 {
    std::env::var(key)
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

fn print_point(p: &ServingPoint) {
    println!(
        "  [{:<9}] offered {:>4}: {:>9.0} req/s  p50 {:>7.2}ms p99 {:>7.2}ms \
         p999 {:>7.2}ms  shed {:>5.1}%  rej {}  err {}  ({} requests, \
         {:.1}s wall)",
        p.phase,
        p.offered,
        p.throughput_rps,
        p.p50_ms,
        p.p99_ms,
        p.p999_ms,
        p.shed_rate() * 100.0,
        p.rejected,
        p.errors,
        p.requests,
        p.wall_s,
    );
}

fn check_accounting(p: &ServingPoint) -> Result<()> {
    ensure!(
        p.completed + p.shed + p.rejected + p.errors == p.requests,
        "[{}] accounting broken: {} + {} + {} + {} != {}",
        p.phase,
        p.completed,
        p.shed,
        p.rejected,
        p.errors,
        p.requests
    );
    Ok(())
}

fn main() -> Result<()> {
    let artifacts = synth::ensure_artifacts()?;
    let total = env_u64("BSKMQ_LOAD_TOTAL", 1_000_000);
    let assert_bounds =
        std::env::var("BSKMQ_LOAD_ASSERT").ok().as_deref() == Some("1");
    println!(
        "artifacts: {} | request budget {} | bounds {}",
        artifacts.display(),
        total,
        if assert_bounds { "ENFORCED" } else { "reported only" },
    );

    let data = ModelData::load(&artifacts, MODEL)?;
    let in_elems: usize = data.x_test.shape[1..].iter().product();
    // a cycle of slightly-varied inputs so batches are never
    // byte-identical across the run
    let inputs: Vec<Vec<f32>> = (0..64)
        .map(|k| {
            let mut xi = data.x_test.data[..in_elems].to_vec();
            xi[0] += k as f32 * 1e-6;
            xi
        })
        .collect();
    let mut points: Vec<ServingPoint> = Vec::new();

    // ----- phase 1: throughput/latency ladder on a fixed pool ---------
    let ladder_deadline = Duration::from_millis(250);
    let ladder: &[usize] = &[1, 8, 32, 128, 256];
    let per_point = (total * 3 / 4 / ladder.len() as u64).max(1);
    let cfg = PoolConfig {
        backend: BackendKind::Native,
        replicas: 4,
        queue_depth: 8192,
        calib_batches: 2,
        request_deadline: ladder_deadline,
        ..PoolConfig::default()
    };
    let mut pool = ModelPool::start(artifacts.clone(), MODEL.to_string(), &cfg)?;
    pool.infer(inputs[0].clone())?; // warm every code path once
    let client = pool.client();
    println!("ladder: {} requests per offered-load point", per_point);
    for &offered in ladder {
        let p = closed_loop(
            &client,
            &inputs,
            MODEL,
            "ladder",
            offered,
            per_point,
            ladder_deadline,
        );
        print_point(&p);
        check_accounting(&p)?;
        if assert_bounds {
            ensure!(p.errors == 0, "ladder@{offered}: {} errors", p.errors);
            ensure!(
                p.rejected == 0,
                "ladder@{offered}: {} rejected with depth 8192",
                p.rejected
            );
            let bound = ladder_deadline.as_secs_f64() * 1e3 + 500.0;
            ensure!(
                p.p999_ms <= bound,
                "ladder@{offered}: p999 {:.1}ms exceeds {:.0}ms",
                p.p999_ms,
                bound
            );
        }
        points.push(p);
    }
    println!("  {}", pool.stats.summary());
    pool.shutdown();

    // ----- phase 2: overload — shedding, not collapse -----------------
    let overload_deadline = Duration::from_millis(25);
    let cfg = PoolConfig {
        backend: BackendKind::Native,
        replicas: 1,
        queue_depth: 4096,
        calib_batches: 2,
        request_deadline: overload_deadline,
        ..PoolConfig::default()
    };
    let mut pool = ModelPool::start(artifacts.clone(), MODEL.to_string(), &cfg)?;
    let client = pool.client();
    let p = closed_loop(
        &client,
        &inputs,
        MODEL,
        "overload",
        256,
        (total / 4).max(1),
        overload_deadline,
    );
    print_point(&p);
    check_accounting(&p)?;
    let stats_shed = pool.shed();
    let prom = {
        use bskmq::obs::prometheus::PromWriter;
        let mut w = PromWriter::new();
        pool.render_prometheus(&mut w);
        w.finish()
    };
    if assert_bounds {
        ensure!(
            p.shed > 0,
            "overload phase shed nothing — 256 clients vs 1 replica with a \
             25ms deadline must overload"
        );
        ensure!(
            stats_shed == p.shed,
            "ServerStats shed {} != client-observed shed {}",
            stats_shed,
            p.shed
        );
        ensure!(
            prom.contains("bskmq_shed_total"),
            "shed counter missing from the Prometheus page"
        );
        let bound = overload_deadline.as_secs_f64() * 1e3 + 500.0;
        ensure!(
            p.p999_ms <= bound,
            "overload: admitted p999 {:.1}ms exceeds {:.0}ms — tail \
             collapse instead of shedding",
            p.p999_ms,
            bound
        );
    }
    points.push(p);
    println!("  {}", pool.stats.summary());
    pool.shutdown();

    // ----- phase 3: queue-depth-driven autoscaling --------------------
    let cfg = PoolConfig {
        backend: BackendKind::Native,
        replicas: 1,
        max_replicas: 4,
        queue_depth: 8192,
        calib_batches: 2,
        request_deadline: ladder_deadline,
        scale_check: Duration::from_millis(5),
        ..PoolConfig::default()
    };
    let mut pool = ModelPool::start(artifacts.clone(), MODEL.to_string(), &cfg)?;
    let client = pool.client();
    let p = closed_loop(
        &client,
        &inputs,
        MODEL,
        "autoscale",
        32,
        (total / 20).max(1),
        ladder_deadline,
    );
    print_point(&p);
    check_accounting(&p)?;
    println!(
        "  autoscale pool finished at {} live replica(s) (bounds 1..4)",
        pool.live_replicas()
    );
    points.push(p);
    pool.shutdown();

    // ----- phase 4: the TCP event front over real sockets -------------
    let cfg = PoolConfig {
        backend: BackendKind::Native,
        replicas: 2,
        queue_depth: 8192,
        calib_batches: 2,
        request_deadline: ladder_deadline,
        ..PoolConfig::default()
    };
    let registry = Arc::new(ModelRegistry::start(
        &artifacts,
        &[MODEL.to_string()],
        &cfg,
    )?);
    let listener = std::net::TcpListener::bind("127.0.0.1:0")?;
    let kind = FrontKind::default_for_platform();
    let mut front = ServeFront::spawn(registry.clone(), listener, kind)?;
    let addr = front.addr();
    let conns = 32usize;
    let per_conn = 200usize;
    let line: String = {
        let floats: Vec<String> =
            inputs[0].iter().map(|v| v.to_string()).collect();
        floats.join(",")
    };
    let t0 = std::time::Instant::now();
    let errors: usize = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..conns)
            .map(|_| {
                let line = &line;
                scope.spawn(move || -> usize {
                    let stream = TcpStream::connect(addr).expect("connect");
                    stream.set_nodelay(true).expect("nodelay");
                    let mut out = stream.try_clone().expect("clone");
                    let mut reader = BufReader::new(stream);
                    // pipelined: write every request, then read every
                    // reply (the event front preserves per-conn order)
                    let mut payload = String::new();
                    for _ in 0..per_conn {
                        payload.push_str(line);
                        payload.push('\n');
                    }
                    out.write_all(payload.as_bytes()).expect("write");
                    let mut errs = 0usize;
                    let mut reply = String::new();
                    for _ in 0..per_conn {
                        reply.clear();
                        reader.read_line(&mut reply).expect("read");
                        if reply.starts_with("error:") {
                            errs += 1;
                        }
                    }
                    errs
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).sum()
    });
    let wall = t0.elapsed().as_secs_f64();
    let tcp_total = (conns * per_conn) as u64;
    println!(
        "  [{:<9}] {} conns x {} pipelined reqs over {} front: {:.0} req/s \
         ({} error replies, {:.1}s wall)",
        "tcp",
        conns,
        per_conn,
        kind.name(),
        tcp_total as f64 / wall,
        errors,
        wall,
    );
    if assert_bounds {
        ensure!(errors == 0, "tcp phase: {errors} error replies");
    }
    points.push(ServingPoint {
        phase: "tcp".to_string(),
        model: MODEL.to_string(),
        offered: conns,
        requests: tcp_total,
        completed: tcp_total - errors as u64,
        shed: 0,
        rejected: 0,
        errors: errors as u64,
        wall_s: wall,
        throughput_rps: tcp_total as f64 / wall,
        p50_ms: 0.0, // per-request timing is hidden by pipelining
        p99_ms: 0.0,
        p999_ms: 0.0,
        deadline_ms: ladder_deadline.as_secs_f64() * 1e3,
        replicas: 2,
        exec_threads: bskmq::backend::native::ops::num_threads(),
        swaps: 0,
        swap_ns: 0,
        inflight_at_swap: 0,
    });
    front.stop();
    drop(front);
    drop(registry);

    let grand: u64 = points.iter().map(|p| p.requests).sum();
    println!("total driven: {grand} requests across {} points", points.len());
    if assert_bounds {
        ensure!(
            grand >= total,
            "harness drove {grand} requests, budget was {total}"
        );
    }

    // emit through the shared BENCH writer (schema v3 serving section)
    if let Ok(dir) = std::env::var("BSKMQ_BENCH_OUT") {
        let mut report = BenchReport::new(&short_rev(), false);
        report.note = format!(
            "benches/serving.rs closed-loop load harness ({} requests)",
            grand
        );
        report.serving = points;
        let path = report.write(std::path::Path::new(&dir))?;
        println!("wrote {}", path.display());
    }
    Ok(())
}
