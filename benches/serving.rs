//! Bench: replica-pool serving throughput vs replica count (the scaling
//! the pool architecture buys on one box), plus the observability
//! surfaces: rejection rate under a saturating burst, queue-wait
//! percentiles, and a BENCH-schema json written through the shared
//! report writer.  Runs on the trained artifacts when present,
//! otherwise on the library's synthetic ones — no Python, no HLO
//! needed.
//!
//!   cargo bench --bench serving
//!   BSKMQ_THREADS=1 cargo bench --bench serving   # per-replica 1 thread
//!   BSKMQ_BENCH_OUT=/tmp cargo bench --bench serving  # also write json

use std::sync::atomic::Ordering;
use std::time::Instant;

use bskmq::backend::BackendKind;
use bskmq::coordinator::server::{ModelPool, PoolConfig};
use bskmq::data::dataset::ModelData;
use bskmq::data::synth;
use bskmq::obs::bench_report::{short_rev, BenchReport, ModelBench};
use bskmq::util::stats::rate;

fn main() -> anyhow::Result<()> {
    // trained artifacts when present, synthetic fallback otherwise
    let artifacts = synth::ensure_artifacts()?;
    println!("artifacts: {}", artifacts.display());
    let data = ModelData::load(&artifacts, "resnet")?;
    let in_elems: usize = data.x_test.shape[1..].iter().product();
    let n_clients = 8usize;
    let reqs_per_client = 64usize;

    let mut best: Option<ModelBench> = None;
    for replicas in [1usize, 2, 4] {
        let cfg = PoolConfig {
            backend: BackendKind::Native,
            replicas,
            queue_depth: 4096,
            calib_batches: 2,
            ..PoolConfig::default()
        };
        let pool =
            ModelPool::start(artifacts.clone(), "resnet".to_string(), &cfg)?;
        // warm up the whole pool once before timing
        pool.infer(data.x_test.data[..in_elems].to_vec())?;
        let t0 = Instant::now();
        std::thread::scope(|s| {
            for c in 0..n_clients {
                let client = pool.client();
                let x_test = &data.x_test;
                s.spawn(move || {
                    for r in 0..reqs_per_client {
                        let idx = (c * 31 + r * 7) % x_test.shape[0];
                        let x = x_test.data
                            [idx * in_elems..(idx + 1) * in_elems]
                            .to_vec();
                        client.infer(x).expect("bench request failed");
                    }
                });
            }
        });
        let wall = t0.elapsed().as_secs_f64();
        let total = (n_clients * reqs_per_client) as f64;
        println!(
            "replicas {replicas}: {total:.0} reqs in {wall:.2}s -> {:7.1} req/s",
            total / wall
        );
        println!("  {}", pool.stats.summary());
        let qw = pool.stats.queue_percentiles_ms(&[0.5, 0.95, 0.99]);
        println!(
            "  queue wait: p50={:.3}ms p95={:.3}ms p99={:.3}ms",
            qw[0], qw[1], qw[2]
        );
        let lat = pool.stats.percentiles_ms(&[0.5, 0.99, 0.999]);
        best = Some(ModelBench {
            model: "resnet".to_string(),
            batch: pool.batch(),
            forwards_per_sec: rate(
                pool.stats.batches.load(Ordering::Relaxed) as f64,
                wall,
            ),
            qfwd_batch_ns: 0, // serving bench: no isolated forward timing
            calib_samples_per_sec: 0.0,
            serve_p50_ms: lat[0],
            serve_p99_ms: lat[1],
            serve_p999_ms: lat[2],
            serve_requests: pool.stats.requests.load(Ordering::Relaxed),
            serve_rejected: pool.rejected(),
            queue_p50_ms: qw[0],
            queue_p99_ms: qw[2],
            per_op_ns: Vec::new(),
        });
    }

    // rejection rate under a saturating burst: a depth-8 queue with one
    // replica cannot absorb 512 back-to-back submits
    let cfg = PoolConfig {
        backend: BackendKind::Native,
        replicas: 1,
        queue_depth: 8,
        calib_batches: 2,
        ..PoolConfig::default()
    };
    let pool =
        ModelPool::start(artifacts.clone(), "resnet".to_string(), &cfg)?;
    let client = pool.client();
    let burst = 512usize;
    let mut kept = Vec::new();
    for _ in 0..burst {
        if let Ok(rx) = client.submit(data.x_test.data[..in_elems].to_vec()) {
            kept.push(rx);
        }
    }
    for rx in &kept {
        let _ = rx.recv();
    }
    let rejected = pool.rejected();
    println!(
        "burst {burst} vs queue depth 8: {} accepted, {} rejected \
         (rejection rate {:.1}%)",
        kept.len(),
        rejected,
        100.0 * rate(rejected as f64, burst as f64),
    );

    // emit the serving numbers through the shared BENCH writer so this
    // bench and `bskmq bench` agree on the schema (opt-in: set
    // BSKMQ_BENCH_OUT to a directory)
    if let Ok(dir) = std::env::var("BSKMQ_BENCH_OUT") {
        let mut report = BenchReport::new(&short_rev(), false);
        report.note =
            "benches/serving.rs: serving-only pass (no qfwd/calib timing)"
                .to_string();
        report.models.extend(best);
        let path = report.write(std::path::Path::new(&dir))?;
        println!("wrote {}", path.display());
    }
    Ok(())
}
