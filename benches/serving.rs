//! Bench: replica-pool serving throughput vs replica count (the scaling
//! the pool architecture buys on one box).  Runs on the trained
//! artifacts when present, otherwise on the library's synthetic ones —
//! no Python, no HLO needed.
//!
//!   cargo bench --bench serving
//!   BSKMQ_THREADS=1 cargo bench --bench serving   # per-replica 1 thread

use std::time::Instant;

use bskmq::backend::BackendKind;
use bskmq::coordinator::server::{ModelPool, PoolConfig};
use bskmq::data::dataset::ModelData;
use bskmq::data::synth;

fn main() -> anyhow::Result<()> {
    // trained artifacts when present, synthetic fallback otherwise
    let artifacts = synth::ensure_artifacts()?;
    println!("artifacts: {}", artifacts.display());
    let data = ModelData::load(&artifacts, "resnet")?;
    let in_elems: usize = data.x_test.shape[1..].iter().product();
    let n_clients = 8usize;
    let reqs_per_client = 64usize;

    for replicas in [1usize, 2, 4] {
        let cfg = PoolConfig {
            backend: BackendKind::Native,
            replicas,
            queue_depth: 4096,
            calib_batches: 2,
            ..PoolConfig::default()
        };
        let pool =
            ModelPool::start(artifacts.clone(), "resnet".to_string(), &cfg)?;
        // warm up the whole pool once before timing
        pool.infer(data.x_test.data[..in_elems].to_vec())?;
        let t0 = Instant::now();
        std::thread::scope(|s| {
            for c in 0..n_clients {
                let client = pool.client();
                let x_test = &data.x_test;
                s.spawn(move || {
                    for r in 0..reqs_per_client {
                        let idx = (c * 31 + r * 7) % x_test.shape[0];
                        let x = x_test.data
                            [idx * in_elems..(idx + 1) * in_elems]
                            .to_vec();
                        client.infer(x).expect("bench request failed");
                    }
                });
            }
        });
        let wall = t0.elapsed().as_secs_f64();
        let total = (n_clients * reqs_per_client) as f64;
        println!(
            "replicas {replicas}: {total:.0} reqs in {wall:.2}s -> {:7.1} req/s",
            total / wall
        );
        println!("  {}", pool.stats.summary());
    }
    Ok(())
}
