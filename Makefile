# Convenience targets.  `make artifacts` needs the Python toolchain
# (jax + the repo's compile package); everything else is pure Rust.

.PHONY: artifacts build test bench

artifacts:
	cd python && python -m compile.aot --out ../artifacts

build:
	cargo build --release

test:
	cargo test -q

bench:
	cargo bench --bench backends
